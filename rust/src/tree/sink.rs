//! Basket destinations for the tree writer.
//!
//! Sinks receive *pooled* payload buffers ([`PayloadBuf`]) tagged with
//! a global append sequence ([`BasketMeta::seq`]). [`FileSink`] appends
//! strictly in sequence order — a small reorder stash absorbs
//! out-of-order completion of pipelined flush tasks — so basket offsets
//! stay monotonic and a pipelined write is **byte-identical** to a
//! serial one. The payload buffer returns to
//! [`crate::compress::pool`] right after the device append
//! ([`FileSink`]) or the copy into the in-memory tree ([`BufferSink`]),
//! closing the zero-allocation loop on the write hot path.
//!
//! Failure model: a panicked flush task poisons at most one sink lock;
//! that surfaces as [`Error::Sync`] on the next sink operation instead
//! of cascading a second panic through the writer.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard};

use crate::compress::pool::Scratch;
use crate::error::{Error, Result};
use crate::format::directory::{BasketInfo, BranchMeta, ClusterSpan, TreeMeta};
use crate::format::writer::FileWriter;
use crate::serial::schema::Schema;
use crate::storage::BackendRef;

use super::buffer::{BasketPayload, TreeBuffer};

/// Pooled compressed-payload buffer handed to a sink; dropping it
/// returns the allocation to the compression scratch pool.
pub type PayloadBuf = Scratch;

/// Identity and placement of one finished basket (classic layout) or
/// page (paged v3 layout).
#[derive(Clone, Copy, Debug)]
pub struct BasketMeta {
    /// Branch index.
    pub branch: usize,
    /// Global append order: cluster-major then branch-minor (classic),
    /// or cluster-major, column-major, page-minor (paged — with each
    /// element page sequenced directly after its offset page, so the
    /// pair is adjacent on disk). [`FileSink`] appends baskets in
    /// exactly this order; the writer assigns it densely from 0.
    pub seq: u64,
    /// Uncompressed payload length.
    pub raw_len: u32,
    /// First entry covered (buffer-relative; *elements* for element
    /// pages).
    pub first_entry: u64,
    /// Entries covered (elements, for element pages).
    pub n_entries: u32,
    /// Is this the element page of a variable-length branch (recorded
    /// in [`BranchMeta::elems`] rather than `baskets`)?
    pub elem: bool,
    /// Compression settings this basket was written with (recorded in
    /// the directory; per-column selection makes this vary by branch).
    pub settings: crate::compress::Settings,
    /// Min/max zone map of the sealed column chunk, captured by the
    /// flush task before serialisation (wire v4; `None` for
    /// non-numeric columns and NaN-bearing pages).
    pub zone: Option<crate::format::ZoneMap>,
}

/// Receives finished (compressed) baskets. Must be thread-safe: during
/// a pipelined flush baskets land concurrently from many tasks, in
/// arbitrary completion order.
pub trait BasketSink: Send + Sync + 'static {
    /// Store one basket. Ownership of the pooled payload transfers to
    /// the sink, which recycles it once the bytes are appended/copied.
    fn put_basket(&self, meta: BasketMeta, payload: PayloadBuf) -> Result<()>;

    /// Record one committed cluster's entry span (paged v3 layout
    /// only; classic writers never call this).
    fn put_cluster(&self, _span: ClusterSpan) -> Result<()> {
        Ok(())
    }
}

/// Poison-proof lock: a panicked flush task must surface as an error
/// on the next sink operation, never as a second panic.
fn lock<T>(m: &Mutex<T>) -> Result<MutexGuard<'_, T>> {
    m.lock()
        .map_err(|_| Error::Sync("basket sink lock poisoned by a panicked flush task".into()))
}

fn unwrap_lock<T>(m: Mutex<T>) -> Result<T> {
    m.into_inner()
        .map_err(|_| Error::Sync("basket sink lock poisoned by a panicked flush task".into()))
}

/// One basket parked until its turn in the append sequence.
struct StashedBasket {
    meta: BasketMeta,
    payload: PayloadBuf,
}

/// Reorder state: the next sequence number due, plus early arrivals.
struct AppendQueue {
    next_seq: u64,
    stash: BTreeMap<u64, StashedBasket>,
}

/// Sink writing straight into an open [`FileWriter`], in basket
/// sequence order.
pub struct FileSink {
    file: std::sync::Arc<FileWriter>,
    baskets: Vec<Mutex<Vec<BasketInfo>>>,
    /// Element pages per branch (paged variable-length branches only).
    elems: Vec<Mutex<Vec<BasketInfo>>>,
    /// Cluster spans committed by a paged writer.
    clusters: Mutex<Vec<ClusterSpan>>,
    order: Mutex<AppendQueue>,
}

impl FileSink {
    pub fn new(file: std::sync::Arc<FileWriter>, n_branches: usize) -> Self {
        FileSink {
            file,
            baskets: (0..n_branches).map(|_| Mutex::new(Vec::new())).collect(),
            elems: (0..n_branches).map(|_| Mutex::new(Vec::new())).collect(),
            clusters: Mutex::new(Vec::new()),
            order: Mutex::new(AppendQueue { next_seq: 0, stash: BTreeMap::new() }),
        }
    }

    /// Append one basket whose turn has come and record its metadata.
    fn append_now(&self, meta: &BasketMeta, payload: &[u8]) -> Result<()> {
        let (offset, crc) = self.file.append(payload)?;
        let list = if meta.elem { &self.elems[meta.branch] } else { &self.baskets[meta.branch] };
        lock(list)?.push(BasketInfo {
            offset,
            comp_len: payload.len() as u32,
            raw_len: meta.raw_len,
            first_entry: meta.first_entry,
            n_entries: meta.n_entries,
            crc,
            settings: meta.settings,
            zone: meta.zone,
        });
        Ok(())
    }

    /// Close this sink's tree and register it with the underlying
    /// [`FileWriter`] for the (possibly multi-tree) footer. Several
    /// sinks of one session may share a `FileWriter` — their appends
    /// interleave, each registers its tree as its writer closes, and
    /// the file is finalised once by
    /// [`FileWriter::finish_registered`].
    pub fn finish_tree(self, name: String, schema: Schema, entries: u64) -> Result<()> {
        let file = self.file.clone();
        let meta = self.into_meta(name, schema, entries)?;
        file.add_tree(meta)
    }

    /// Drain collected metadata into a [`TreeMeta`]. Errors when a
    /// sequence number never arrived (its flush task failed) or a lock
    /// was poisoned.
    pub fn into_meta(self, name: String, schema: Schema, entries: u64) -> Result<TreeMeta> {
        let queue = unwrap_lock(self.order)?;
        if !queue.stash.is_empty() {
            return Err(Error::Sync(format!(
                "{} basket(s) missing from the append sequence (a flush task failed)",
                queue.stash.len()
            )));
        }
        let mut branches = Vec::with_capacity(self.baskets.len());
        for ((m, e), f) in self.baskets.into_iter().zip(self.elems).zip(&schema.fields) {
            let mut baskets = unwrap_lock(m)?;
            baskets.sort_by_key(|b| b.first_entry);
            let mut elems = unwrap_lock(e)?;
            // Element pages arrive in append (= page) order; the sort
            // is a stable no-op that mirrors the row-page handling.
            elems.sort_by_key(|b| b.first_entry);
            branches.push(BranchMeta { name: f.name.clone(), ty: f.ty, baskets, elems });
        }
        let clusters = unwrap_lock(self.clusters)?;
        Ok(TreeMeta { name, schema, entries, branches, clusters })
    }
}

impl BasketSink for FileSink {
    fn put_basket(&self, meta: BasketMeta, payload: PayloadBuf) -> Result<()> {
        let mut queue = lock(&self.order)?;
        if meta.seq != queue.next_seq {
            // Not our turn yet: park the payload (pool-owned either
            // way) and let the basket whose turn it is drain us.
            queue.stash.insert(meta.seq, StashedBasket { meta, payload });
            return Ok(());
        }
        self.append_now(&meta, &payload)?;
        drop(payload); // recycle before draining successors
        // Advance the cursor per drained basket, not once at the end:
        // if an append fails mid-drain (a transient device fault that
        // exhausted the backend's retries), the queue must keep an
        // exact record of what landed — the failed basket goes back in
        // the stash and `next_seq` stays on it, so nothing is silently
        // lost and nothing can be appended twice. Transient faults
        // normally never get this far: [`FileWriter::append`] reserves
        // the offset first, so a resilient backend retries the
        // write_at against the *same* offset and the file stays
        // byte-identical (see `storage::resilient`).
        queue.next_seq = meta.seq + 1;
        while let Some(s) = queue.stash.remove(&queue.next_seq) {
            if let Err(e) = self.append_now(&s.meta, &s.payload) {
                queue.stash.insert(s.meta.seq, s);
                return Err(e);
            }
            queue.next_seq += 1;
        }
        Ok(())
    }

    fn put_cluster(&self, span: ClusterSpan) -> Result<()> {
        lock(&self.clusters)?.push(span);
        Ok(())
    }
}

/// Sink accumulating into an in-memory [`TreeBuffer`]. Payload bytes
/// are copied out (right-sized, no pool slack) so the pooled buffer
/// recycles immediately — the tree buffer may sit in a merge queue
/// arbitrarily long. Arrival order does not matter: baskets are sorted
/// by entry range when the buffer is taken.
pub struct BufferSink {
    branches: Vec<Mutex<Vec<BasketPayload>>>,
    elems: Vec<Mutex<Vec<BasketPayload>>>,
    clusters: Mutex<Vec<ClusterSpan>>,
    schema: Schema,
}

impl BufferSink {
    pub fn new(schema: Schema) -> Self {
        let n = schema.len();
        BufferSink {
            branches: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
            elems: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
            clusters: Mutex::new(Vec::new()),
            schema,
        }
    }

    pub fn into_buffer(self, entries: u64) -> Result<TreeBuffer> {
        let mut buf = TreeBuffer::new(self.schema.clone());
        buf.entries = entries;
        for ((dst, src), es) in buf.branches.iter_mut().zip(self.branches).zip(self.elems) {
            dst.baskets = unwrap_lock(src)?;
            dst.baskets.sort_by_key(|b| b.first_entry);
            dst.elems = unwrap_lock(es)?;
            dst.elems.sort_by_key(|b| b.first_entry);
        }
        buf.clusters = unwrap_lock(self.clusters)?;
        Ok(buf)
    }
}

impl BasketSink for BufferSink {
    fn put_basket(&self, meta: BasketMeta, payload: PayloadBuf) -> Result<()> {
        let list = if meta.elem { &self.elems[meta.branch] } else { &self.branches[meta.branch] };
        lock(list)?.push(BasketPayload {
            bytes: payload.to_vec(),
            raw_len: meta.raw_len,
            first_entry: meta.first_entry,
            n_entries: meta.n_entries,
            settings: meta.settings,
            zone: meta.zone,
        });
        Ok(())
    }

    fn put_cluster(&self, span: ClusterSpan) -> Result<()> {
        lock(&self.clusters)?.push(span);
        Ok(())
    }
}

/// Open a fresh file writer on `backend` (helper used by examples and
/// benches). Attach one [`FileSink`] per tree — a session may write
/// several trees of one file concurrently, each closing with
/// [`FileSink::finish_tree`], and the file finalises once via
/// [`FileWriter::finish_registered`].
pub fn file_writer(backend: BackendRef) -> Result<std::sync::Arc<FileWriter>> {
    Ok(std::sync::Arc::new(FileWriter::create(backend)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::HEADER_LEN;
    use crate::serial::schema::{ColumnType, Field};
    use crate::storage::mem::MemBackend;
    use std::sync::Arc;

    fn schema2() -> Schema {
        Schema::new(vec![Field::new("a", ColumnType::F32), Field::new("b", ColumnType::I32)])
    }

    fn bm(branch: usize, seq: u64, raw_len: u32, first_entry: u64, n_entries: u32) -> BasketMeta {
        BasketMeta {
            branch,
            seq,
            raw_len,
            first_entry,
            n_entries,
            elem: false,
            settings: crate::compress::Settings::uncompressed(),
            zone: None,
        }
    }

    #[test]
    fn file_sink_appends_in_sequence_order() {
        let be = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be).unwrap());
        let sink = FileSink::new(fw.clone(), 2);
        // out-of-order arrival (pipelined flush): seq 1 and 2 stash...
        sink.put_basket(bm(0, 1, 12, 100, 50), vec![1, 2, 3].into()).unwrap();
        sink.put_basket(bm(1, 2, 4, 0, 150), vec![6].into()).unwrap();
        assert_eq!(fw.position(), HEADER_LEN, "nothing appends before seq 0 lands");
        // ...and seq 0 drains all three in order.
        sink.put_basket(bm(0, 0, 8, 0, 100), vec![4, 5].into()).unwrap();
        assert_eq!(fw.position(), HEADER_LEN + 6);
        let meta = sink.into_meta("t".into(), schema2(), 150).unwrap();
        assert_eq!(meta.branches[0].baskets[0].first_entry, 0);
        assert_eq!(meta.branches[0].baskets[0].offset, HEADER_LEN);
        assert_eq!(meta.branches[0].baskets[1].offset, HEADER_LEN + 2);
        assert_eq!(meta.branches[1].baskets[0].offset, HEADER_LEN + 5);
        meta.check().unwrap();
    }

    #[test]
    fn file_sink_detects_missing_sequence() {
        let be = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be).unwrap());
        let sink = FileSink::new(fw, 1);
        sink.put_basket(bm(0, 1, 4, 10, 10), vec![9].into()).unwrap();
        // seq 0 never arrives (its task failed): close must error, not
        // silently drop the stashed basket.
        assert!(sink.into_meta("t".into(), schema2(), 20).is_err());
    }

    #[test]
    fn mid_drain_append_failure_keeps_queue_consistent() {
        use crate::storage::fault::{FaultDirection, FaultKind, FaultPlan, FaultyBackend};
        use crate::storage::Backend;
        // Header + two basket appends fit the fault budget; the third
        // append (draining seq 2) hits a hard device error.
        let faulty = Arc::new(FaultyBackend::new(
            Arc::new(MemBackend::new()),
            FaultKind::Hard,
            FaultDirection::Writes,
            FaultPlan::AfterN(3),
        ));
        let fw = Arc::new(FileWriter::create(faulty.clone()).unwrap());
        let sink = FileSink::new(fw.clone(), 1);
        sink.put_basket(bm(0, 1, 4, 10, 10), vec![9, 9].into()).unwrap();
        sink.put_basket(bm(0, 2, 4, 20, 10), vec![8, 8].into()).unwrap();
        sink.put_basket(bm(0, 3, 4, 30, 10), vec![7, 7].into()).unwrap();
        assert_eq!(fw.position(), HEADER_LEN, "everything stashed until seq 0");
        // seq 0 drains: 0 and 1 append, then seq 2's device write
        // faults mid-drain and must surface — not vanish.
        assert!(
            sink.put_basket(bm(0, 0, 4, 0, 10), vec![6, 6].into()).is_err(),
            "exhausted fault budget must surface from the drain"
        );
        assert_eq!(faulty.injected(), 1);
        // The two baskets that landed are intact and in order (reads
        // are not faulted).
        let mut got = [0u8; 4];
        faulty.read_at(HEADER_LEN, &mut got).unwrap();
        assert_eq!(&got, &[6, 6, 9, 9], "seq 0 then seq 1, byte-exact");
        // The faulted basket went back to the stash with `next_seq`
        // still pointing at it: close reports the undrained baskets
        // instead of silently dropping them or appending seq 3 past
        // the hole.
        assert!(sink.into_meta("t".into(), schema2(), 40).is_err());
    }

    #[test]
    fn two_trees_one_file_written_concurrently() {
        use crate::compress::{Codec, Settings};
        use crate::format::reader::FileReader;
        use crate::serial::value::Value;
        use crate::session::{Session, SessionConfig};
        use crate::tree::reader::TreeReader;
        use crate::tree::writer::{FlushMode, TreeWriter, WriterConfig};

        let be = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
        let pool = Arc::new(crate::imt::Pool::new(3));
        let session = Session::with_pool(pool, SessionConfig::for_writers(2, 2));
        let schema = schema2();
        let cfg = WriterConfig {
            basket_entries: 32,
            compression: Settings::new(Codec::Lz4r, 2),
            flush: FlushMode::Pipelined,
            ..Default::default()
        };
        std::thread::scope(|s| {
            for (name, base) in [("alpha", 0i32), ("beta", 1000i32)] {
                let sink = FileSink::new(fw.clone(), schema.len());
                let mut w =
                    TreeWriter::attached(schema.clone(), sink, cfg.clone(), &session);
                let schema = schema.clone();
                s.spawn(move || {
                    for i in 0..100 {
                        w.fill(vec![Value::F32(i as f32), Value::I32(base + i)]).unwrap();
                    }
                    let (sink, entries, _) = w.close().unwrap();
                    sink.finish_tree(name.into(), schema, entries).unwrap();
                });
            }
        });
        fw.finish_registered().unwrap();

        let file = Arc::new(FileReader::open(be).unwrap());
        for (name, base) in [("alpha", 0i32), ("beta", 1000i32)] {
            let r = TreeReader::open(file.clone(), name).unwrap();
            assert_eq!(r.entries(), 100);
            let cols = r.read_all().unwrap();
            for i in 0..100usize {
                assert_eq!(cols[1].get(i), Some(Value::I32(base + i as i32)));
            }
        }
    }

    #[test]
    fn buffer_sink_builds_tree_buffer() {
        let sink = BufferSink::new(schema2());
        sink.put_basket(bm(0, 0, 40, 0, 10), vec![9; 10].into()).unwrap();
        sink.put_basket(bm(1, 1, 40, 0, 10), vec![8; 5].into()).unwrap();
        let buf = sink.into_buffer(10).unwrap();
        assert_eq!(buf.entries, 10);
        assert_eq!(buf.branches[0].baskets.len(), 1);
        assert_eq!(buf.stored_bytes(), 15);
    }
}
