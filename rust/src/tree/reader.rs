//! Tree reader: basket fetch / decompress / deserialise primitives.
//!
//! The reader exposes exactly the decomposition the paper parallelises:
//! `fetch` (storage), `decompress`, `deserialise` per (branch, basket).
//! The scheduling strategies — per-column tasks (Fig 1), per-basket
//! tasks with interleaved processing (Fig 2) — live in
//! [`crate::coordinator::read`]; this type stays policy-free.

use std::sync::Arc;

use crate::cache::{ClusterStream, PrefetchOptions};
use crate::compress;
use crate::error::{Error, Result};
use crate::format::directory::TreeMeta;
use crate::format::reader::FileReader;
use crate::serial::column::ColumnData;
use crate::serial::value::Row;
use crate::session::Session;

/// Read-side handle on one tree of an open file.
pub struct TreeReader {
    file: Arc<FileReader>,
    meta: TreeMeta,
}

impl TreeReader {
    pub fn open(file: Arc<FileReader>, tree: &str) -> Result<Self> {
        let meta = file
            .directory()
            .tree(tree)
            .ok_or_else(|| Error::Format(format!("no tree '{tree}' in file")))?
            .clone();
        Ok(TreeReader { file, meta })
    }

    /// First tree in the file (the common single-tree case).
    pub fn open_first(file: Arc<FileReader>) -> Result<Self> {
        let meta = file
            .directory()
            .trees
            .first()
            .ok_or_else(|| Error::Format("file contains no trees".into()))?
            .clone();
        Ok(TreeReader { file, meta })
    }

    pub fn meta(&self) -> &TreeMeta {
        &self.meta
    }

    /// The open file this reader reads from.
    pub fn file(&self) -> &Arc<FileReader> {
        &self.file
    }

    /// Open a prefetching [`ClusterStream`] over this tree: coalesced
    /// window fetches ahead of the consumer, per-basket decode on the
    /// IMT pool, decoded clusters yielded strictly in order (see
    /// [`crate::cache`]). Runs under a private single-reader session.
    pub fn stream(&self, opts: &PrefetchOptions) -> Result<ClusterStream> {
        ClusterStream::open(self, opts)
    }

    /// As [`TreeReader::stream`], attached to a shared [`Session`]:
    /// fetch/decode tasks join the session's completion domain and
    /// read-ahead admission draws from its shared read budget.
    pub fn stream_in_session(
        &self,
        opts: &PrefetchOptions,
        session: &Session,
    ) -> Result<ClusterStream> {
        ClusterStream::open_in_session(self, opts, session)
    }

    pub fn entries(&self) -> u64 {
        self.meta.entries
    }

    pub fn n_branches(&self) -> usize {
        self.meta.branches.len()
    }

    /// Fetch the stored (compressed) bytes of basket `k` of branch `b`.
    pub fn fetch_raw(&self, b: usize, k: usize) -> Result<Vec<u8>> {
        let info = &self.meta.branches[b].baskets[k];
        self.file.fetch_basket(info)
    }

    /// Decompress + deserialise previously fetched basket bytes. The
    /// decompression scratch comes from [`compress::pool`], so this
    /// allocates nothing per basket beyond the decoded column itself.
    pub fn decode(&self, b: usize, k: usize, raw: &[u8]) -> Result<ColumnData> {
        let branch = &self.meta.branches[b];
        decode_basket_bytes(branch.ty, &branch.baskets[k], raw)
    }

    /// Fetch + decompress + deserialise one basket — the unit of the
    /// basket-granularity read pipeline (paper §2.1–§2.2). Both
    /// scratch buffers (compressed fetch, decompressed wire bytes) are
    /// pooled; steady-state reads allocate only the decoded column.
    /// On a paged variable-length branch, basket `k` is the offset
    /// page and its paired element page is fetched and zipped with it.
    pub fn read_basket(&self, b: usize, k: usize) -> Result<ColumnData> {
        let branch = &self.meta.branches[b];
        if branch.is_paged_list() {
            let off = &branch.baskets[k];
            let el = &branch.elems[k];
            let mut raw_off = compress::pool::get(off.comp_len as usize);
            self.file.fetch_basket_into(off, &mut raw_off)?;
            let mut raw_el = compress::pool::get(el.comp_len as usize);
            self.file.fetch_basket_into(el, &mut raw_el)?;
            return decode_page_pair(off, &raw_off, el, &raw_el);
        }
        let info = &branch.baskets[k];
        let mut raw = compress::pool::get(info.comp_len as usize);
        self.file.fetch_basket_into(info, &mut raw)?;
        self.decode(b, k, &raw)
    }

    /// Serial read of one whole branch.
    pub fn read_branch(&self, b: usize) -> Result<ColumnData> {
        let branch = &self.meta.branches[b];
        let mut out = ColumnData::new(branch.ty);
        for k in 0..branch.baskets.len() {
            out.append(&self.read_basket(b, k)?)?;
        }
        Ok(out)
    }

    /// Serial read of every branch (the IMT-off baseline for Fig 1).
    pub fn read_all(&self) -> Result<Vec<ColumnData>> {
        (0..self.n_branches()).map(|b| self.read_branch(b)).collect()
    }

    /// Reassemble rows from fully decoded columns.
    pub fn rows(&self, cols: &[ColumnData]) -> Result<Vec<Row>> {
        crate::serial::streamer::Streamer::new(self.meta.schema.clone()).unsplit(cols)
    }
}

/// Decompress + deserialise one basket's stored bytes into a column —
/// the single decode-and-verify invariant, shared by
/// [`TreeReader::decode`] and the prefetcher's per-basket decode
/// tasks ([`crate::cache`]). The decompression scratch is pooled.
pub(crate) fn decode_basket_bytes(
    ty: crate::serial::schema::ColumnType,
    info: &crate::format::directory::BasketInfo,
    raw: &[u8],
) -> Result<ColumnData> {
    let mut bytes = compress::pool::get(info.raw_len as usize);
    compress::decompress_into(raw, &mut bytes)?;
    if bytes.len() != info.raw_len as usize {
        return Err(Error::Format(format!(
            "basket at offset {}: decompressed to {} bytes, expected {}",
            info.offset,
            bytes.len(),
            info.raw_len
        )));
    }
    ColumnData::decode(ty, &bytes, info.n_entries as usize)
}

/// Decode one paged offset/element page pair back into a
/// variable-length column: the offset page holds page-relative I64
/// end-offsets (one per row), the element page the flattened F32
/// values; [`ColumnData::zip_list`] validates and reassembles them.
/// Shared by [`TreeReader::read_basket`] and the prefetcher's paired
/// decode tasks.
pub(crate) fn decode_page_pair(
    off_info: &crate::format::directory::BasketInfo,
    off_raw: &[u8],
    el_info: &crate::format::directory::BasketInfo,
    el_raw: &[u8],
) -> Result<ColumnData> {
    let offsets =
        decode_basket_bytes(crate::serial::schema::ColumnType::I64, off_info, off_raw)?;
    let elems =
        decode_basket_bytes(crate::serial::schema::ColumnType::F32, el_info, el_raw)?;
    ColumnData::zip_list(&offsets, &elems)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Codec, Settings};
    use crate::format::writer::FileWriter;
    use crate::format::Directory;
    use crate::serial::schema::{ColumnType, Field, Schema};
    use crate::serial::value::Value;
    use crate::storage::mem::MemBackend;
    use crate::tree::sink::FileSink;
    use crate::tree::writer::{FlushMode, TreeWriter, WriterConfig};

    fn build_file(n: u64, basket: usize) -> Arc<FileReader> {
        let schema = Schema::new(vec![
            Field::new("e", ColumnType::F64),
            Field::new("id", ColumnType::I64),
            Field::new("tag", ColumnType::Bytes),
        ]);
        let be = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
        let sink = FileSink::new(fw.clone(), schema.len());
        let cfg = WriterConfig {
            basket_entries: basket,
            compression: Settings::new(Codec::Rzip, 4),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        for i in 0..n {
            w.fill(vec![
                Value::F64(i as f64 * 1.5),
                Value::I64(i as i64),
                Value::Bytes(format!("t{}", i % 7).into_bytes()),
            ])
            .unwrap();
        }
        let (sink, entries, _) = w.close().unwrap();
        let meta = sink.into_meta("events".into(), schema, entries).unwrap();
        fw.finish(&Directory { trees: vec![meta] }).unwrap();
        Arc::new(FileReader::open(be).unwrap())
    }

    #[test]
    fn write_read_roundtrip() {
        let file = build_file(1000, 128);
        let r = TreeReader::open(file, "events").unwrap();
        assert_eq!(r.entries(), 1000);
        let cols = r.read_all().unwrap();
        assert_eq!(cols[0].len(), 1000);
        let rows = r.rows(&cols).unwrap();
        assert_eq!(rows[42][0], Value::F64(63.0));
        assert_eq!(rows[999][1], Value::I64(999));
        assert_eq!(rows[8][2], Value::Bytes(b"t1".to_vec()));
    }

    #[test]
    fn per_basket_primitives() {
        let file = build_file(300, 100);
        let r = TreeReader::open(file, "events").unwrap();
        let branch = &r.meta().branches[1];
        assert_eq!(branch.baskets.len(), 3);
        let raw = r.fetch_raw(1, 2).unwrap();
        let col = r.decode(1, 2, &raw).unwrap();
        assert_eq!(col.len(), 100);
        assert_eq!(col.get(0), Some(Value::I64(200)));
    }

    #[test]
    fn read_basket_matches_fetch_plus_decode() {
        let file = build_file(300, 100);
        let r = TreeReader::open(file, "events").unwrap();
        let raw = r.fetch_raw(1, 2).unwrap();
        let via_decode = r.decode(1, 2, &raw).unwrap();
        let via_read = r.read_basket(1, 2).unwrap();
        assert_eq!(via_decode, via_read);
    }

    #[test]
    fn steady_state_reads_hit_the_buffer_pool() {
        // Acceptance: scratch buffers on the decompress path come from
        // the pool. The shelf is thread-local, so concurrent tests can
        // only *add* hits; this thread's second pass must reuse every
        // buffer its first pass returned.
        let file = build_file(1000, 100); // 3 branches x 10 baskets
        let r = TreeReader::open(file, "events").unwrap();
        let n_baskets: usize =
            r.meta().branches.iter().map(|b| b.baskets.len()).sum();
        let first = r.read_all().unwrap(); // warm the shelf
        let hits_before = crate::compress::pool::stats().hits;
        let second = r.read_all().unwrap(); // steady state
        let hits_after = crate::compress::pool::stats().hits;
        assert_eq!(first, second);
        // two pooled buffers per basket: compressed fetch + wire bytes
        assert!(
            hits_after - hits_before >= 2 * n_baskets as u64,
            "steady-state read must draw all scratch from the pool: \
             {} hits across {} baskets",
            hits_after - hits_before,
            n_baskets
        );
    }

    fn paged_rows(n: u32) -> Vec<Vec<Value>> {
        (0..n)
            .map(|i| {
                let list: Vec<f32> = (0..i % 6).map(|j| (i * 2 + j) as f32 * 0.25).collect();
                vec![Value::F32(i as f32), Value::I64(i as i64 * 3), Value::ListF32(list)]
            })
            .collect()
    }

    fn paged_schema() -> Schema {
        Schema::new(vec![
            Field::new("x", ColumnType::F32),
            Field::new("id", ColumnType::I64),
            Field::new("hits", ColumnType::ListF32),
        ])
    }

    fn write_paged(
        be: Arc<MemBackend>,
        version: u32,
        rows: &[Vec<Value>],
        cluster: usize,
        page: usize,
    ) -> Result<()> {
        use crate::format::writer::FileWriter;
        use crate::tree::writer::Layout;
        let schema = paged_schema();
        let fw = Arc::new(FileWriter::create_versioned(be, version)?);
        let sink = FileSink::new(fw.clone(), schema.len());
        let cfg = WriterConfig {
            basket_entries: cluster,
            compression: Settings::new(Codec::Lz4r, 3),
            flush: FlushMode::Serial,
            layout: Layout::Paged { page_entries: page },
            ..Default::default()
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        for r in rows {
            w.fill(r.clone())?;
        }
        let (sink, entries, _) = w.close()?;
        let meta = sink.into_meta("events".into(), schema, entries)?;
        fw.finish(&Directory { trees: vec![meta] })
    }

    fn dump(be: &MemBackend) -> Vec<u8> {
        use crate::storage::Backend;
        let mut bytes = vec![0u8; be.len().unwrap() as usize];
        be.read_at(0, &mut bytes).unwrap();
        bytes
    }

    /// Acceptance (ISSUE 8): the paged v3 format round-trips —
    /// variable-length data included — and rewriting the decoded rows
    /// through the same configuration reproduces the file byte for
    /// byte (byte-stable round-trip).
    #[test]
    fn paged_v3_roundtrip_is_byte_stable() {
        let rows = paged_rows(500);
        let be = Arc::new(MemBackend::new());
        write_paged(be.clone(), crate::format::VERSION, &rows, 128, 48).unwrap();
        let file = Arc::new(FileReader::open(be.clone()).unwrap());
        assert_eq!(file.version(), crate::format::VERSION);
        let r = TreeReader::open(file, "events").unwrap();
        assert_eq!(r.entries(), 500);
        let meta = r.meta().clone();
        assert!(meta.branches[2].is_paged_list());
        assert_eq!(meta.clusters.len(), 4, "128-entry clusters over 500 rows");
        meta.check().unwrap();
        let cols = r.read_all().unwrap();
        let decoded = r.rows(&cols).unwrap();
        assert_eq!(decoded.len(), 500);
        for (i, (got, want)) in decoded.iter().zip(&rows).enumerate() {
            assert_eq!(got, want, "row {i}");
        }
        // Rewrite the decoded rows with the same config: identical bytes.
        let be2 = Arc::new(MemBackend::new());
        write_paged(be2.clone(), crate::format::VERSION, &decoded, 128, 48).unwrap();
        assert_eq!(dump(&be), dump(&be2), "v3 round-trip must be byte-stable");
    }

    /// Older wire versions keep decoding: classic-layout content writes
    /// and reads on v1 (no per-basket settings) and v2 (settings, no
    /// page lists) exactly as before the paged format landed.
    #[test]
    fn v1_and_v2_classic_files_still_decode() {
        use crate::format::writer::FileWriter;
        let schema = Schema::new(vec![
            Field::new("x", ColumnType::F32),
            Field::new("id", ColumnType::I64),
        ]);
        let mut reference: Option<Vec<ColumnData>> = None;
        for version in [1u32, 2, 3] {
            let be = Arc::new(MemBackend::new());
            let fw = Arc::new(FileWriter::create_versioned(be.clone(), version).unwrap());
            let sink = FileSink::new(fw.clone(), schema.len());
            let cfg = WriterConfig {
                basket_entries: 64,
                compression: Settings::new(Codec::Rzip, 3),
                flush: FlushMode::Serial,
                ..Default::default()
            };
            let mut w = TreeWriter::new(schema.clone(), sink, cfg);
            for i in 0..300i64 {
                w.fill(vec![Value::F32(i as f32 * 0.5), Value::I64(i)]).unwrap();
            }
            let (sink, entries, _) = w.close().unwrap();
            let meta = sink.into_meta("events".into(), schema.clone(), entries).unwrap();
            fw.finish(&Directory { trees: vec![meta] }).unwrap();
            let file = Arc::new(FileReader::open(be).unwrap());
            assert_eq!(file.version(), version);
            let r = TreeReader::open_first(file).unwrap();
            let cols = r.read_all().unwrap();
            match &reference {
                None => reference = Some(cols),
                Some(want) => assert_eq!(&cols, want, "v{version} decode diverged"),
            }
        }
    }

    /// The paged layout needs the v3 wire: a v1 writer must refuse to
    /// serialise page lists rather than silently dropping them.
    #[test]
    fn paged_content_on_v1_wire_is_rejected() {
        let rows = paged_rows(100);
        let be = Arc::new(MemBackend::new());
        let err = write_paged(be, 1, &rows, 64, 16);
        assert!(err.is_err(), "v1 wire must reject page lists");
    }

    #[test]
    fn missing_tree_is_error() {
        let file = build_file(10, 10);
        assert!(TreeReader::open(file, "nope").is_err());
    }

    #[test]
    fn open_first_works() {
        let file = build_file(10, 10);
        let r = TreeReader::open_first(file).unwrap();
        assert_eq!(r.meta().name, "events");
    }
}
