//! Tree reader: basket fetch / decompress / deserialise primitives.
//!
//! The reader exposes exactly the decomposition the paper parallelises:
//! `fetch` (storage), `decompress`, `deserialise` per (branch, basket).
//! The scheduling strategies — per-column tasks (Fig 1), per-basket
//! tasks with interleaved processing (Fig 2) — live in
//! [`crate::coordinator::read`]; this type stays policy-free.

use std::sync::Arc;

use crate::cache::{ClusterStream, PrefetchOptions};
use crate::compress;
use crate::error::{Error, Result};
use crate::format::directory::TreeMeta;
use crate::format::reader::FileReader;
use crate::serial::column::ColumnData;
use crate::serial::value::Row;
use crate::session::Session;

/// Read-side handle on one tree of an open file.
pub struct TreeReader {
    file: Arc<FileReader>,
    meta: TreeMeta,
}

impl TreeReader {
    pub fn open(file: Arc<FileReader>, tree: &str) -> Result<Self> {
        let meta = file
            .directory()
            .tree(tree)
            .ok_or_else(|| Error::Format(format!("no tree '{tree}' in file")))?
            .clone();
        Ok(TreeReader { file, meta })
    }

    /// First tree in the file (the common single-tree case).
    pub fn open_first(file: Arc<FileReader>) -> Result<Self> {
        let meta = file
            .directory()
            .trees
            .first()
            .ok_or_else(|| Error::Format("file contains no trees".into()))?
            .clone();
        Ok(TreeReader { file, meta })
    }

    pub fn meta(&self) -> &TreeMeta {
        &self.meta
    }

    /// The open file this reader reads from.
    pub fn file(&self) -> &Arc<FileReader> {
        &self.file
    }

    /// Open a prefetching [`ClusterStream`] over this tree: coalesced
    /// window fetches ahead of the consumer, per-basket decode on the
    /// IMT pool, decoded clusters yielded strictly in order (see
    /// [`crate::cache`]). Runs under a private single-reader session.
    pub fn stream(&self, opts: &PrefetchOptions) -> Result<ClusterStream> {
        ClusterStream::open(self, opts)
    }

    /// As [`TreeReader::stream`], attached to a shared [`Session`]:
    /// fetch/decode tasks join the session's completion domain and
    /// read-ahead admission draws from its shared read budget.
    pub fn stream_in_session(
        &self,
        opts: &PrefetchOptions,
        session: &Session,
    ) -> Result<ClusterStream> {
        ClusterStream::open_in_session(self, opts, session)
    }

    pub fn entries(&self) -> u64 {
        self.meta.entries
    }

    pub fn n_branches(&self) -> usize {
        self.meta.branches.len()
    }

    /// Fetch the stored (compressed) bytes of basket `k` of branch `b`.
    pub fn fetch_raw(&self, b: usize, k: usize) -> Result<Vec<u8>> {
        let info = &self.meta.branches[b].baskets[k];
        self.file.fetch_basket(info)
    }

    /// Decompress + deserialise previously fetched basket bytes. The
    /// decompression scratch comes from [`compress::pool`], so this
    /// allocates nothing per basket beyond the decoded column itself.
    pub fn decode(&self, b: usize, k: usize, raw: &[u8]) -> Result<ColumnData> {
        let branch = &self.meta.branches[b];
        decode_basket_bytes(branch.ty, &branch.baskets[k], raw)
    }

    /// Fetch + decompress + deserialise one basket — the unit of the
    /// basket-granularity read pipeline (paper §2.1–§2.2). Both
    /// scratch buffers (compressed fetch, decompressed wire bytes) are
    /// pooled; steady-state reads allocate only the decoded column.
    pub fn read_basket(&self, b: usize, k: usize) -> Result<ColumnData> {
        let info = &self.meta.branches[b].baskets[k];
        let mut raw = compress::pool::get(info.comp_len as usize);
        self.file.fetch_basket_into(info, &mut raw)?;
        self.decode(b, k, &raw)
    }

    /// Serial read of one whole branch.
    pub fn read_branch(&self, b: usize) -> Result<ColumnData> {
        let branch = &self.meta.branches[b];
        let mut out = ColumnData::new(branch.ty);
        for k in 0..branch.baskets.len() {
            out.append(&self.read_basket(b, k)?)?;
        }
        Ok(out)
    }

    /// Serial read of every branch (the IMT-off baseline for Fig 1).
    pub fn read_all(&self) -> Result<Vec<ColumnData>> {
        (0..self.n_branches()).map(|b| self.read_branch(b)).collect()
    }

    /// Reassemble rows from fully decoded columns.
    pub fn rows(&self, cols: &[ColumnData]) -> Result<Vec<Row>> {
        crate::serial::streamer::Streamer::new(self.meta.schema.clone()).unsplit(cols)
    }
}

/// Decompress + deserialise one basket's stored bytes into a column —
/// the single decode-and-verify invariant, shared by
/// [`TreeReader::decode`] and the prefetcher's per-basket decode
/// tasks ([`crate::cache`]). The decompression scratch is pooled.
pub(crate) fn decode_basket_bytes(
    ty: crate::serial::schema::ColumnType,
    info: &crate::format::directory::BasketInfo,
    raw: &[u8],
) -> Result<ColumnData> {
    let mut bytes = compress::pool::get(info.raw_len as usize);
    compress::decompress_into(raw, &mut bytes)?;
    if bytes.len() != info.raw_len as usize {
        return Err(Error::Format(format!(
            "basket at offset {}: decompressed to {} bytes, expected {}",
            info.offset,
            bytes.len(),
            info.raw_len
        )));
    }
    ColumnData::decode(ty, &bytes, info.n_entries as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Codec, Settings};
    use crate::format::writer::FileWriter;
    use crate::format::Directory;
    use crate::serial::schema::{ColumnType, Field, Schema};
    use crate::serial::value::Value;
    use crate::storage::mem::MemBackend;
    use crate::tree::sink::FileSink;
    use crate::tree::writer::{FlushMode, TreeWriter, WriterConfig};

    fn build_file(n: u64, basket: usize) -> Arc<FileReader> {
        let schema = Schema::new(vec![
            Field::new("e", ColumnType::F64),
            Field::new("id", ColumnType::I64),
            Field::new("tag", ColumnType::Bytes),
        ]);
        let be = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
        let sink = FileSink::new(fw.clone(), schema.len());
        let cfg = WriterConfig {
            basket_entries: basket,
            compression: Settings::new(Codec::Rzip, 4),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        for i in 0..n {
            w.fill(vec![
                Value::F64(i as f64 * 1.5),
                Value::I64(i as i64),
                Value::Bytes(format!("t{}", i % 7).into_bytes()),
            ])
            .unwrap();
        }
        let (sink, entries, _) = w.close().unwrap();
        let meta = sink.into_meta("events".into(), schema, entries).unwrap();
        fw.finish(&Directory { trees: vec![meta] }).unwrap();
        Arc::new(FileReader::open(be).unwrap())
    }

    #[test]
    fn write_read_roundtrip() {
        let file = build_file(1000, 128);
        let r = TreeReader::open(file, "events").unwrap();
        assert_eq!(r.entries(), 1000);
        let cols = r.read_all().unwrap();
        assert_eq!(cols[0].len(), 1000);
        let rows = r.rows(&cols).unwrap();
        assert_eq!(rows[42][0], Value::F64(63.0));
        assert_eq!(rows[999][1], Value::I64(999));
        assert_eq!(rows[8][2], Value::Bytes(b"t1".to_vec()));
    }

    #[test]
    fn per_basket_primitives() {
        let file = build_file(300, 100);
        let r = TreeReader::open(file, "events").unwrap();
        let branch = &r.meta().branches[1];
        assert_eq!(branch.baskets.len(), 3);
        let raw = r.fetch_raw(1, 2).unwrap();
        let col = r.decode(1, 2, &raw).unwrap();
        assert_eq!(col.len(), 100);
        assert_eq!(col.get(0), Some(Value::I64(200)));
    }

    #[test]
    fn read_basket_matches_fetch_plus_decode() {
        let file = build_file(300, 100);
        let r = TreeReader::open(file, "events").unwrap();
        let raw = r.fetch_raw(1, 2).unwrap();
        let via_decode = r.decode(1, 2, &raw).unwrap();
        let via_read = r.read_basket(1, 2).unwrap();
        assert_eq!(via_decode, via_read);
    }

    #[test]
    fn steady_state_reads_hit_the_buffer_pool() {
        // Acceptance: scratch buffers on the decompress path come from
        // the pool. The shelf is thread-local, so concurrent tests can
        // only *add* hits; this thread's second pass must reuse every
        // buffer its first pass returned.
        let file = build_file(1000, 100); // 3 branches x 10 baskets
        let r = TreeReader::open(file, "events").unwrap();
        let n_baskets: usize =
            r.meta().branches.iter().map(|b| b.baskets.len()).sum();
        let first = r.read_all().unwrap(); // warm the shelf
        let hits_before = crate::compress::pool::stats().hits;
        let second = r.read_all().unwrap(); // steady state
        let hits_after = crate::compress::pool::stats().hits;
        assert_eq!(first, second);
        // two pooled buffers per basket: compressed fetch + wire bytes
        assert!(
            hits_after - hits_before >= 2 * n_baskets as u64,
            "steady-state read must draw all scratch from the pool: \
             {} hits across {} baskets",
            hits_after - hits_before,
            n_baskets
        );
    }

    #[test]
    fn missing_tree_is_error() {
        let file = build_file(10, 10);
        assert!(TreeReader::open(file, "nope").is_err());
    }

    #[test]
    fn open_first_works() {
        let file = build_file(10, 10);
        let r = TreeReader::open_first(file).unwrap();
        assert_eq!(r.meta().name, "events");
    }
}
