//! Columnar trees (TTree/TBranch/TBasket analogue).
//!
//! A tree is a table: one typed branch per schema field, each branch
//! stored as a sequence of compressed baskets. Baskets are flushed in
//! aligned *clusters* (all branches cut at the same entry numbers), so
//! any contiguous entry range can be read back by touching exactly the
//! overlapping baskets of each selected branch.
//!
//! The writer emits baskets through a [`sink::BasketSink`], which is
//! either a real file ([`sink::FileSink`]) or an in-memory buffer
//! ([`buffer::TreeBuffer`] via [`sink::BufferSink`]) — the latter is
//! what `TBufferMerger` workers produce. With implicit multi-threading
//! enabled, flushes run as an asynchronous block-granularity pipeline
//! on the IMT pool (paper §3.1): the producer keeps filling while
//! earlier clusters serialise + compress, payload buffers are pooled
//! end to end, and `FileSink` appends in sequence order so pipelined
//! output is byte-identical to a serial write — see [`writer`] for the
//! full ordering and failure model.

pub mod buffer;
pub mod reader;
pub mod sink;
pub mod sizer;
pub mod writer;

pub use buffer::TreeBuffer;
pub use reader::TreeReader;
pub use sink::{BasketMeta, BasketSink, BufferSink, FileSink, PayloadBuf};
pub use sizer::{AdaptiveConfig, ClusterSizer, ClusterSizing, SizerSummary};
pub use writer::{FlushGranularity, FlushMode, TreeWriter, WriteStats, WriterConfig};
