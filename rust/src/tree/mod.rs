//! Columnar trees (TTree/TBranch/TBasket analogue).
//!
//! A tree is a table: one typed branch per schema field, each branch
//! stored as a sequence of compressed baskets. Baskets are flushed in
//! aligned *clusters* (all branches cut at the same entry numbers), so
//! any contiguous entry range can be read back by touching exactly the
//! overlapping baskets of each selected branch.
//!
//! The writer emits baskets through a [`sink::BasketSink`], which is
//! either a real file ([`sink::FileSink`]) or an in-memory buffer
//! ([`buffer::TreeBuffer`] via [`sink::BufferSink`]) — the latter is
//! what `TBufferMerger` workers produce. Per-branch serialisation +
//! compression during a flush goes through the IMT pool when implicit
//! multi-threading is enabled (paper §3.1).

pub mod buffer;
pub mod reader;
pub mod sink;
pub mod writer;

pub use buffer::TreeBuffer;
pub use reader::TreeReader;
pub use sink::{BasketSink, BufferSink, FileSink};
pub use writer::{TreeWriter, WriterConfig};
