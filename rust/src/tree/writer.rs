//! Tree writer: accumulates rows (or whole column blocks), cuts aligned
//! basket clusters, and serialises + compresses each branch's basket —
//! in parallel across branches when IMT is enabled (paper §3.1).

use std::sync::Arc;
use std::time::Duration;

use crate::compress::{self, Settings};
use crate::error::{Error, Result};
use crate::imt;
use crate::metrics::{Recorder, SpanKind};
use crate::serial::column::ColumnData;
use crate::serial::schema::Schema;
use crate::serial::streamer::Streamer;
use crate::serial::value::Row;

use super::sink::BasketSink;

/// Tuning for a tree writer.
#[derive(Clone, Debug)]
pub struct WriterConfig {
    /// Entries per basket cluster (all branches cut together).
    pub basket_entries: usize,
    /// Compression settings applied to every branch.
    pub compression: Settings,
    /// Use the IMT pool for per-branch serialise+compress during flush.
    pub parallel_flush: bool,
}

impl Default for WriterConfig {
    fn default() -> Self {
        WriterConfig {
            basket_entries: 4096,
            compression: Settings::default_compressed(),
            parallel_flush: true,
        }
    }
}

/// Columnar tree writer over any [`BasketSink`].
pub struct TreeWriter<S: BasketSink> {
    streamer: Streamer,
    config: WriterConfig,
    sink: S,
    columns: Vec<ColumnData>,
    buffered: usize,
    entries: u64,
    recorder: Option<Arc<Recorder>>,
}

impl<S: BasketSink> TreeWriter<S> {
    pub fn new(schema: Schema, sink: S, config: WriterConfig) -> Self {
        let streamer = Streamer::new(schema);
        let columns = streamer.make_columns();
        TreeWriter { streamer, config, sink, columns, buffered: 0, entries: 0, recorder: None }
    }

    /// Attach a span recorder (Fig 7 instrumentation).
    pub fn with_recorder(mut self, r: Arc<Recorder>) -> Self {
        self.recorder = Some(r);
        self
    }

    pub fn schema(&self) -> &Schema {
        self.streamer.schema()
    }

    pub fn entries(&self) -> u64 {
        self.entries
    }

    /// Append one row; may trigger a cluster flush.
    pub fn fill(&mut self, row: Row) -> Result<()> {
        self.streamer.fill(&mut self.columns, row)?;
        self.buffered += 1;
        self.entries += 1;
        if self.buffered >= self.config.basket_entries {
            self.flush()?;
        }
        Ok(())
    }

    /// Bulk append: one `ColumnData` per branch, all the same length.
    /// This is the zero-boxing path used when landing PJRT-generated
    /// event blocks.
    pub fn fill_columns(&mut self, block: &[ColumnData]) -> Result<()> {
        if block.len() != self.columns.len() {
            return Err(Error::Schema(format!(
                "block has {} columns, schema has {}",
                block.len(),
                self.columns.len()
            )));
        }
        let n = block.first().map(|c| c.len()).unwrap_or(0);
        for c in block {
            if c.len() != n {
                return Err(Error::Schema("ragged column block".into()));
            }
        }
        for (dst, src) in self.columns.iter_mut().zip(block) {
            dst.append(src)?;
        }
        self.buffered += n;
        self.entries += n as u64;
        // Chunked flushing: honour basket_entries even for bulk appends
        // larger than one basket (the granularity Figs 1/2 rely on).
        while self.buffered >= self.config.basket_entries {
            let chunk = self.config.basket_entries;
            self.flush_chunk(chunk)?;
        }
        Ok(())
    }

    /// Flush everything still buffered (tail baskets included).
    pub fn flush(&mut self) -> Result<()> {
        while self.buffered > 0 {
            let chunk = self.buffered.min(self.config.basket_entries);
            self.flush_chunk(chunk)?;
        }
        Ok(())
    }

    /// Serialise + compress + sink the first `chunk` buffered entries.
    fn flush_chunk(&mut self, chunk: usize) -> Result<()> {
        if chunk == 0 {
            return Ok(());
        }
        let n_entries = chunk as u32;
        let first_entry = self.entries - self.buffered as u64;
        let cols: Vec<_> =
            self.columns.iter_mut().map(|c| c.drain_front(chunk)).collect();
        let settings = self.config.compression;
        let sink = &self.sink;
        let recorder = self.recorder.clone();

        let one = |i: usize, col: &ColumnData| -> Result<()> {
            // Serialisation scratch is pooled; only the compressed
            // payload (whose ownership passes to the sink) is a fresh
            // allocation. This is the Riley/Jones fix: per-basket
            // flush cost no longer includes allocator round-trips.
            let mut raw = compress::pool::get(col.byte_len());
            let ((), ser_span) = timed(|| col.encode_into(&mut raw));
            let (payload, cmp_span) = timed(|| compress::compress(settings, &raw));
            if let Some(r) = &recorder {
                r.push(SpanKind::Serialize, ser_span.0, ser_span.1);
                r.push(SpanKind::Compress, cmp_span.0, cmp_span.1);
            }
            sink.put_basket(i, payload, raw.len() as u32, first_entry, n_entries)
        };

        if self.config.parallel_flush && imt::is_enabled() {
            let results: Vec<Result<()>> =
                imt::parallel_map(cols.len(), |i| one(i, &cols[i]));
            for r in results {
                r?;
            }
        } else {
            for (i, col) in cols.iter().enumerate() {
                one(i, col)?;
            }
        }
        self.buffered -= chunk;
        Ok(())
    }

    /// Flush the tail and hand back the sink (with the final entry count).
    pub fn close(mut self) -> Result<(S, u64)> {
        self.flush()?;
        Ok((self.sink, self.entries))
    }
}

/// Time a closure against the recorder epoch-free monotonic clock.
/// Returns (value, (start, end)) as durations since an arbitrary t0
/// shared within the process.
fn timed<R>(f: impl FnOnce() -> R) -> (R, (Duration, Duration)) {
    let t0 = process_epoch().elapsed();
    let out = f();
    let t1 = process_epoch().elapsed();
    (out, (t0, t1))
}

fn process_epoch() -> &'static std::time::Instant {
    static EPOCH: std::sync::OnceLock<std::time::Instant> = std::sync::OnceLock::new();
    EPOCH.get_or_init(std::time::Instant::now)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::serial::schema::{ColumnType, Field};
    use crate::serial::value::Value;
    use crate::tree::sink::BufferSink;

    fn schema() -> Schema {
        Schema::new(vec![Field::new("x", ColumnType::F32), Field::new("n", ColumnType::I32)])
    }

    fn config(basket: usize) -> WriterConfig {
        WriterConfig {
            basket_entries: basket,
            compression: Settings::new(Codec::Lz4r, 3),
            parallel_flush: false,
        }
    }

    #[test]
    fn clusters_are_aligned_and_cover_all_entries() {
        let mut w = TreeWriter::new(schema(), BufferSink::new(schema()), config(100));
        for i in 0..250 {
            w.fill(vec![Value::F32(i as f32), Value::I32(i)]).unwrap();
        }
        let (sink, entries) = w.close().unwrap();
        assert_eq!(entries, 250);
        let buf = sink.into_buffer(entries);
        // 100 + 100 + 50
        for br in &buf.branches {
            let counts: Vec<u32> = br.baskets.iter().map(|b| b.n_entries).collect();
            assert_eq!(counts, vec![100, 100, 50]);
            let firsts: Vec<u64> = br.baskets.iter().map(|b| b.first_entry).collect();
            assert_eq!(firsts, vec![0, 100, 200]);
        }
    }

    #[test]
    fn fill_columns_bulk_path() {
        let mut w = TreeWriter::new(schema(), BufferSink::new(schema()), config(64));
        let block = vec![
            ColumnData::F32((0..100).map(|i| i as f32).collect()),
            ColumnData::I32((0..100).collect()),
        ];
        w.fill_columns(&block).unwrap();
        w.fill_columns(&block).unwrap();
        let (sink, entries) = w.close().unwrap();
        assert_eq!(entries, 200);
        let buf = sink.into_buffer(entries);
        let total: u32 = buf.branches[0].baskets.iter().map(|b| b.n_entries).sum();
        assert_eq!(total, 200);
    }

    #[test]
    fn fill_columns_rejects_ragged_and_wrong_arity() {
        let mut w = TreeWriter::new(schema(), BufferSink::new(schema()), config(64));
        assert!(w.fill_columns(&[ColumnData::F32(vec![1.0])]).is_err());
        assert!(w
            .fill_columns(&[ColumnData::F32(vec![1.0]), ColumnData::I32(vec![1, 2])])
            .is_err());
    }

    #[test]
    fn empty_close() {
        let w = TreeWriter::new(schema(), BufferSink::new(schema()), config(10));
        let (sink, entries) = w.close().unwrap();
        assert_eq!(entries, 0);
        assert!(sink.into_buffer(0).is_empty());
    }
}
