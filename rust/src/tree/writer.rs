//! Tree writer: accumulates rows (or whole column blocks), cuts aligned
//! basket clusters, and serialises + compresses each branch's basket on
//! the IMT pool.
//!
//! The flush is an asynchronous, block-granularity *pipeline* (paper
//! §3.1; Riley & Jones' multi-threaded CMS output): `flush_chunk`
//! takes ownership of the drained columns and submits one task per
//! branch basket — further decomposed into per-[`compress::MAX_BLOCK`]
//! subtasks under [`FlushGranularity::Block`] — to an
//! [`imt::TaskGroup`], so [`TreeWriter::fill`] / `fill_columns` keep
//! accumulating the next cluster while earlier clusters compress in
//! the background.
//!
//! Ordering and failure model:
//! * every basket carries a global sequence number (cluster-major,
//!   branch-minor); [`super::sink::FileSink`] appends in exactly that
//!   order, so a pipelined write is **byte-identical** to the serial
//!   writer's output;
//! * task failures land in a shared error slot and surface from the
//!   next `fill`/`flush`/`close`; task *panics* are caught by the task
//!   group and reported by `close` as [`Error::Sync`] — a bad basket
//!   aborts the write cleanly, it never hangs `close()` or cascades;
//! * backpressure is *admission*: every pipelined cluster takes one
//!   slot of its [`crate::session::Session`]'s shared in-flight budget
//!   before spawning and releases it when its last task completes. A
//!   standalone writer ([`TreeWriter::new`]) wraps itself in a private
//!   session whose budget is [`WriterConfig::max_inflight_clusters`];
//!   a writer opened with [`TreeWriter::attached`] shares the session
//!   budget with every other writer of the job under per-writer
//!   fair-share caps, so N writers together stay within one global
//!   memory bound and none can starve the rest. Either way, a blocked
//!   producer helps execute flush tasks (the wait is accounted as
//!   *stall* in [`WriteStats`]) instead of ballooning memory.
//!
//! Scratch and payload buffers both come from [`compress::pool`], so a
//! steady-state flush performs zero allocator round-trips end to end:
//! serialise into a pooled buffer, compress into a pooled buffer, sink
//! appends/copies and recycles it.
//!
//! Cluster sizes are fixed or **adaptive** ([`WriterConfig::sizing`],
//! [`super::sizer`]): after every pipelined cluster the writer feeds
//! its stall/compress counters and per-writer admission-wait count to
//! a [`ClusterSizer`], which may step the next cluster's entry count
//! ×2/÷2 (hysteresis, warmup, min/max clamps, replayable trace).

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::compress::select::{CodecSelection, ColumnSelector, Observation, SelectSummary};
use crate::compress::{self, Settings};
use crate::error::{Error, Result};
use crate::format::directory::ClusterSpan;
use crate::imt::{ClusterGuard, Pool, TaskGroup};
use crate::metrics::{timed, Recorder, Registry, SpanKind};
use crate::session::{Session, WriterRegistration};
use crate::serial::column::ColumnData;
use crate::serial::schema::{ColumnType, Schema};
use crate::serial::streamer::Streamer;
use crate::serial::value::Row;

use super::sink::{BasketMeta, BasketSink, PayloadBuf};
use super::sizer::{ClusterSizer, ClusterSizing, Decision, SizerSummary};

/// How `fill` hands a cut cluster to the serialise+compress stage.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FlushMode {
    /// Everything inline on the filling thread (baseline; also what
    /// the other modes degrade to when IMT is off).
    Serial,
    /// Fan the cluster out on the IMT pool and *block* until it is
    /// stored: per-flush parallelism only, the pre-pipeline write path.
    Parallel,
    /// Fan out and return: the producer keeps accumulating the next
    /// cluster while earlier clusters compress (paper §3.1 pipeline).
    #[default]
    Pipelined,
}

/// On-disk layout of each flushed cluster.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Layout {
    /// One basket per branch per cluster (the TTree analogue; wire
    /// v1/v2 compatible).
    #[default]
    Classic,
    /// RNTuple-style paged layout (wire v3): each branch's cluster
    /// chunk is cut into `page_entries`-row pages, sealed (serialised +
    /// compressed) as independent tasks — no single per-cluster flush
    /// lock; the session budget arbitrates only cluster commits — and
    /// appended column-major within the cluster. Variable-length
    /// branches split into offset/element page pairs.
    Paged {
        /// Rows per page (clamped to ≥ 1). Pages are also the units of
        /// projection-pushdown reads, so smaller pages trade directory
        /// size for finer fetch granularity.
        page_entries: usize,
    },
}

/// Default rows per page for [`Layout::paged`].
pub const DEFAULT_PAGE_ENTRIES: usize = 1024;

impl Layout {
    /// The paged layout at the default page size.
    pub fn paged() -> Self {
        Layout::Paged { page_entries: DEFAULT_PAGE_ENTRIES }
    }
}

/// Task decomposition of one flushed cluster.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FlushGranularity {
    /// One task per branch basket: scales as `min(branches, T)` within
    /// a flush (kept as the comparison baseline).
    Branch,
    /// One subtask per [`compress::MAX_BLOCK`] chunk of each basket,
    /// so fat baskets split across workers. Stored bytes are identical
    /// either way (blocks are cut at the same boundaries).
    #[default]
    Block,
}

/// Tuning for a tree writer.
#[derive(Clone, Debug)]
pub struct WriterConfig {
    /// Entries per basket cluster (all branches cut together). Under
    /// [`ClusterSizing::Adaptive`] this is the *starting* size; the
    /// sizer then adjusts between clusters within its clamp band.
    pub basket_entries: usize,
    /// Compression settings applied to every branch.
    pub compression: Settings,
    /// Flush scheduling: serial, parallel-blocking, or pipelined.
    pub flush: FlushMode,
    /// Task decomposition for parallel/pipelined flushes.
    pub granularity: FlushGranularity,
    /// Pipelined mode: this writer's cap on clusters in flight before
    /// `fill` blocks (bounds buffered memory; wait time is accounted
    /// as stall). Standalone writers own a budget of exactly this
    /// size; writers attached to a shared [`crate::session::Session`]
    /// are additionally clamped to their fair share of the session
    /// budget.
    pub max_inflight_clusters: usize,
    /// Cluster-size policy: keep `basket_entries` fixed, or let the
    /// per-writer [`ClusterSizer`] adjust the effective size between
    /// clusters from the observed stall/compress ratio and the
    /// session's admission-wait feedback (pipelined flushes only; the
    /// serial and parallel-blocking paths always behave as `Fixed`).
    pub sizing: ClusterSizing,
    /// Codec policy: apply `compression` globally, or let a per-column
    /// [`ColumnSelector`] probe each branch's early baskets across a
    /// candidate ladder and commit the best ratio × throughput point
    /// per branch (`compression` stays the fallback until a column
    /// commits). Works under every flush mode; each basket records its
    /// own settings in the directory.
    pub selection: CodecSelection,
    /// On-disk cluster layout: classic one-basket-per-branch clusters,
    /// or the paged v3 layout ([`Layout::Paged`]) with per-column
    /// pages and offset/element pairs for variable-length branches.
    pub layout: Layout,
}

impl Default for WriterConfig {
    fn default() -> Self {
        WriterConfig {
            basket_entries: 4096,
            compression: Settings::default_compressed(),
            flush: FlushMode::default(),
            granularity: FlushGranularity::default(),
            max_inflight_clusters: 4,
            sizing: ClusterSizing::Fixed,
            selection: CodecSelection::Global,
            layout: Layout::Classic,
        }
    }
}

/// Flush-pipeline accounting, returned by [`TreeWriter::close`].
#[derive(Clone, Copy, Debug, Default)]
pub struct WriteStats {
    /// Total serialisation CPU across all flush tasks.
    pub serialize: Duration,
    /// Total compression CPU across all flush tasks.
    pub compress: Duration,
    /// Producer stall: wall time `fill`/`flush`/`close` spent blocked
    /// on flush work (backpressure waits plus the close join).
    /// Strictly below `compress` means the overlap is real — the
    /// producer kept working while baskets compressed elsewhere.
    pub stall: Duration,
    /// Baskets handed to the sink.
    pub baskets: u64,
    /// Cluster-size report: the band of sizes the writer actually cut
    /// (min = max = `basket_entries` under [`ClusterSizing::Fixed`]).
    pub sizing: SizerSummary,
    /// Per-column codec-selection report (all-zero under
    /// [`CodecSelection::Global`]).
    pub selection: SelectSummary,
}

/// Counters shared with flush tasks.
#[derive(Default)]
struct TaskCounters {
    serialize_ns: AtomicU64,
    compress_ns: AtomicU64,
    baskets: AtomicU64,
}

/// First task failure wins; later ones are dropped (one abort reason).
#[derive(Default)]
struct ErrorSlot {
    failed: AtomicBool,
    first: Mutex<Option<Error>>,
}

impl ErrorSlot {
    fn set(&self, e: Error) {
        // A poisoned slot means a task panicked mid-set; that panic is
        // reported separately by the task group, so just recover.
        let mut g = self.first.lock().unwrap_or_else(|p| p.into_inner());
        if g.is_none() {
            *g = Some(e);
        }
        self.failed.store(true, Ordering::Release);
    }

    /// Surface (and consume) the first recorded failure. The fast path
    /// is one atomic load.
    fn check(&self) -> Result<()> {
        if !self.failed.load(Ordering::Acquire) {
            return Ok(());
        }
        let mut g = self.first.lock().unwrap_or_else(|p| p.into_inner());
        Err(g
            .take()
            .unwrap_or_else(|| Error::Sync("write pipeline already failed".into())))
    }
}

/// Columnar tree writer over any [`BasketSink`].
pub struct TreeWriter<S: BasketSink> {
    streamer: Streamer,
    config: WriterConfig,
    sink: Arc<S>,
    columns: Vec<ColumnData>,
    buffered: usize,
    entries: u64,
    /// The session's span recorder (disabled unless the session traced;
    /// every record call is then a single branch).
    recorder: Recorder,
    /// The session's metrics registry (always on — feeds the
    /// basket-compress latency histogram from flush tasks).
    registry: Registry,
    group: TaskGroup,
    /// Membership in the session's shared in-flight budget: every
    /// pipelined cluster is admitted through it before spawning.
    admission: WriterRegistration,
    /// Per-writer cluster-size controller (a no-op pass-through of
    /// `basket_entries` under [`ClusterSizing::Fixed`]).
    sizer: ClusterSizer,
    /// Per-column codec selectors (empty under
    /// [`CodecSelection::Global`]). Owned by the producer thread, like
    /// the sizer — flush tasks never touch them directly.
    selectors: Vec<ColumnSelector>,
    /// Observations flowing back from flush tasks to the selectors:
    /// each stored basket pushes one `(branch, Observation)`; the
    /// producer drains the inbox at the start of every flush.
    select_inbox: Arc<Mutex<Vec<(usize, Observation)>>>,
    counters: Arc<TaskCounters>,
    errors: Arc<ErrorSlot>,
    /// Global basket sequence: cluster-major, branch-minor (classic);
    /// cluster-major, column-major, page-minor (paged).
    next_seq: u64,
    /// Paged layout: elements written so far per branch — the global
    /// element coordinate of each variable-length branch's next
    /// element page.
    elem_counts: Vec<u64>,
    /// Producer-side stall accumulator (only the filling thread adds).
    stall: Duration,
}

impl<S: BasketSink> TreeWriter<S> {
    /// Standalone writer: wraps itself in a private single-writer
    /// [`Session`] on the global IMT pool, preserving the historical
    /// per-writer `max_inflight_clusters` semantics.
    pub fn new(schema: Schema, sink: S, config: WriterConfig) -> Self {
        let session = Session::solo(config.max_inflight_clusters);
        Self::attached(schema, sink, config, &session)
    }

    /// Writer attached to a shared [`Session`]: flush tasks run on the
    /// session's pool and cluster admission draws from the session's
    /// *shared* budget (fair-share capped), so many writers together
    /// stay within one global in-flight bound.
    pub fn attached(schema: Schema, sink: S, config: WriterConfig, session: &Session) -> Self {
        let streamer = Streamer::new(schema);
        let columns = streamer.make_columns();
        let group = session.task_group();
        let admission = session.register_writer(config.max_inflight_clusters);
        let sizer = ClusterSizer::new(config.basket_entries, config.sizing);
        let selectors = match &config.selection {
            CodecSelection::Global => Vec::new(),
            CodecSelection::PerColumn(sc) => (0..columns.len())
                .map(|_| ColumnSelector::new(sc.clone(), config.compression))
                .collect(),
        };
        let elem_counts = vec![0u64; columns.len()];
        TreeWriter {
            streamer,
            config,
            sink: Arc::new(sink),
            columns,
            buffered: 0,
            entries: 0,
            recorder: session.recorder().clone(),
            registry: session.metrics().clone(),
            group,
            admission,
            sizer,
            selectors,
            select_inbox: Arc::new(Mutex::new(Vec::new())),
            counters: Arc::new(TaskCounters::default()),
            errors: Arc::new(ErrorSlot::default()),
            next_seq: 0,
            elem_counts,
            stall: Duration::ZERO,
        }
    }

    /// Attach a span recorder (Fig 7 instrumentation). Clones share
    /// the recorder's buffers, so unwrapping the `Arc` here keeps the
    /// historical callers working while the writer stores the plain
    /// cheap-clone handle.
    pub fn with_recorder(mut self, r: Arc<Recorder>) -> Self {
        self.recorder = (*r).clone();
        self
    }

    /// Run flush tasks on a specific pool instead of the global IMT
    /// pool (dedicated writer pools, hermetic tests). Equivalent to a
    /// private single-writer session on that pool.
    pub fn with_pool(mut self, pool: Arc<Pool>) -> Self {
        let session = Session::with_pool(
            pool,
            crate::session::SessionConfig {
                max_inflight_clusters: self.config.max_inflight_clusters.max(1),
                ..Default::default()
            },
        );
        self.group = session.task_group();
        self.admission = session.register_writer(self.config.max_inflight_clusters);
        self
    }

    /// Admission diagnostics: the most clusters this writer ever had
    /// in flight (fairness tests assert it stays within the share).
    pub fn admission_high_water(&self) -> usize {
        self.admission.high_water()
    }

    /// The writer's current fair share of its session's budget.
    pub fn admission_fair_share(&self) -> usize {
        self.admission.fair_share()
    }

    /// Admissions of this writer that had to wait for budget capacity
    /// — the session-pressure feedback the adaptive sizer consumes.
    pub fn admission_waits(&self) -> u64 {
        self.admission.waits()
    }

    /// Entries the next cluster will hold (`basket_entries` under
    /// [`ClusterSizing::Fixed`]; the sizer's current target under
    /// [`ClusterSizing::Adaptive`]).
    pub fn cluster_target(&self) -> usize {
        self.sizer.target()
    }

    /// The adaptive sizer's replayable decision trace so far (empty
    /// under [`ClusterSizing::Fixed`]). Snapshot it before `close`.
    pub fn sizer_trace(&self) -> &[Decision] {
        self.sizer.trace()
    }

    /// One column's codec-selection decision trace so far (empty under
    /// [`CodecSelection::Global`]). Snapshot it before `close`.
    pub fn selector_trace(&self, branch: usize) -> &[compress::select::Decision] {
        match self.selectors.get(branch) {
            Some(s) => s.trace(),
            None => &[],
        }
    }

    /// The codec a column's selector has committed to, if any (`None`
    /// while probing or under [`CodecSelection::Global`]).
    pub fn selector_choice(&self, branch: usize) -> Option<Settings> {
        self.selectors.get(branch).and_then(|s| s.current_choice())
    }

    pub fn schema(&self) -> &Schema {
        self.streamer.schema()
    }

    pub fn entries(&self) -> u64 {
        self.entries
    }

    /// Append one row; may trigger a cluster flush.
    pub fn fill(&mut self, row: Row) -> Result<()> {
        self.errors.check()?;
        self.streamer.fill(&mut self.columns, row)?;
        self.buffered += 1;
        self.entries += 1;
        if self.buffered >= self.sizer.target() {
            self.flush()?;
        }
        Ok(())
    }

    /// Bulk append: one `ColumnData` per branch, all the same length.
    /// This is the zero-boxing path used when landing PJRT-generated
    /// event blocks.
    pub fn fill_columns(&mut self, block: &[ColumnData]) -> Result<()> {
        self.errors.check()?;
        if block.len() != self.columns.len() {
            return Err(Error::Schema(format!(
                "block has {} columns, schema has {}",
                block.len(),
                self.columns.len()
            )));
        }
        let n = block.first().map(|c| c.len()).unwrap_or(0);
        for c in block {
            if c.len() != n {
                return Err(Error::Schema("ragged column block".into()));
            }
        }
        for (dst, src) in self.columns.iter_mut().zip(block) {
            dst.append(src)?;
        }
        self.buffered += n;
        self.entries += n as u64;
        // Chunked flushing: honour the cluster target even for bulk
        // appends larger than one basket (the granularity Figs 1/2
        // rely on). Re-read the target every iteration — an adaptive
        // sizer may step between clusters.
        while self.buffered >= self.sizer.target() {
            let chunk = self.sizer.target();
            self.flush_chunk(chunk)?;
        }
        Ok(())
    }

    /// Flush everything still buffered (tail baskets included). In
    /// pipelined mode this submits the tail and returns; completion is
    /// awaited by [`TreeWriter::close`].
    pub fn flush(&mut self) -> Result<()> {
        while self.buffered > 0 {
            let chunk = self.buffered.min(self.sizer.target());
            self.flush_chunk(chunk)?;
        }
        Ok(())
    }

    /// Cut the first `chunk` buffered entries into one cluster — one
    /// basket per branch (classic) or per-column page runs (paged) —
    /// and hand the tasks to the flush stage per `config.flush`.
    fn flush_chunk(&mut self, chunk: usize) -> Result<()> {
        if chunk == 0 {
            return Ok(());
        }
        self.errors.check()?;
        self.drain_observations();
        // Backpressure = admission: a pipelined cluster takes one slot
        // of the session's shared budget *before* spawning, and the
        // slot frees when the cluster's last task drops its guard. The
        // wait helps execute pool jobs and is accounted as stall.
        let admission: Option<Arc<ClusterGuard>> =
            if self.config.flush == FlushMode::Pipelined {
                let t0 = Instant::now();
                let guard = self.admission.acquire();
                self.stall += t0.elapsed();
                Some(Arc::new(guard))
            } else {
                None
            };
        let first_entry = self.entries - self.buffered as u64;
        match self.config.layout {
            Layout::Classic => {
                for branch in 0..self.columns.len() {
                    let col = self.columns[branch].drain_front(chunk);
                    self.submit_task(branch, col, first_entry, chunk as u32, false, &admission);
                }
            }
            Layout::Paged { page_entries } => {
                // Record the cluster cut up front — it is producer-side
                // metadata, independent of when the page tasks finish.
                self.sink.put_cluster(ClusterSpan {
                    first_entry,
                    n_entries: chunk as u64,
                })?;
                let page_entries = page_entries.max(1);
                for branch in 0..self.columns.len() {
                    let mut cluster_col = self.columns[branch].drain_front(chunk);
                    let mut start = 0usize;
                    while start < chunk {
                        let n = page_entries.min(chunk - start);
                        let page = cluster_col.drain_front(n);
                        let page_first = first_entry + start as u64;
                        if page.column_type() == ColumnType::ListF32 {
                            // Offset/element pair: the offset page holds
                            // page-relative end offsets (rows), the
                            // element page the flattened values; its
                            // seq comes directly after the offset
                            // page's, so the pair is adjacent on disk.
                            let (offsets, elems) = page.split_list()?;
                            let n_elems = elems.len();
                            let elem_first = self.elem_counts[branch];
                            self.elem_counts[branch] += n_elems as u64;
                            self.submit_task(
                                branch, offsets, page_first, n as u32, false, &admission,
                            );
                            self.submit_task(
                                branch,
                                elems,
                                elem_first,
                                n_elems as u32,
                                true,
                                &admission,
                            );
                        } else {
                            self.submit_task(
                                branch, page, page_first, n as u32, false, &admission,
                            );
                        }
                        start += n;
                    }
                }
            }
        }
        drop(admission); // tasks hold the cluster's slot from here on
        self.buffered -= chunk;
        let done = match self.config.flush {
            FlushMode::Serial => self.errors.check(),
            FlushMode::Parallel => {
                let t0 = Instant::now();
                let joined = self.group.join();
                self.stall += t0.elapsed();
                joined?;
                self.errors.check()
            }
            FlushMode::Pipelined => self.errors.check(),
        };
        // Feed one observation window back to the adaptive sizer: the
        // cumulative producer stall, compression CPU completed so far
        // and this writer's admission-wait count. Only the pipelined
        // flush has a backpressure signal to read.
        if self.config.flush == FlushMode::Pipelined && self.sizer.is_adaptive() {
            let compress =
                Duration::from_nanos(self.counters.compress_ns.load(Ordering::Relaxed));
            self.sizer.observe(self.stall, compress, self.admission.waits());
        }
        done
    }

    /// Submit one basket/page task for `branch`, assigning it the next
    /// global sequence number. `elem` marks element pages of paged
    /// variable-length branches (routed to the directory's element
    /// list, entry coordinates counting elements).
    fn submit_task(
        &mut self,
        branch: usize,
        col: ColumnData,
        first_entry: u64,
        n_entries: u32,
        elem: bool,
        admission: &Option<Arc<ClusterGuard>>,
    ) {
        let settings = match self.selectors.get_mut(branch) {
            Some(sel) => sel.next_settings(),
            None => self.config.compression,
        };
        let task = BasketTask {
            col,
            meta: BasketMeta {
                branch,
                seq: self.next_seq,
                raw_len: 0, // set after serialisation
                first_entry,
                n_entries,
                settings,
                elem,
                zone: None, // captured by the flush task before sealing
            },
            sink: self.sink.clone(),
            settings,
            granularity: self.config.granularity,
            recorder: self.recorder.clone(),
            registry: self.registry.clone(),
            page: matches!(self.config.layout, Layout::Paged { .. }),
            counters: self.counters.clone(),
            errors: self.errors.clone(),
            obs: (!self.selectors.is_empty()).then(|| self.select_inbox.clone()),
            obs_compress_ns: AtomicU64::new(0),
            _admission: admission.clone(),
        };
        self.next_seq += 1;
        if self.config.flush == FlushMode::Serial {
            let t0 = Instant::now();
            task.run(None);
            self.stall += t0.elapsed();
        } else {
            let group = self.group.clone();
            self.group.spawn(move || task.run(Some(&group)));
        }
    }

    /// Relay completed-basket measurements from the flush-task inbox to
    /// the per-column selectors. Producer thread only, so the selectors
    /// themselves need no locking.
    fn drain_observations(&mut self) {
        if self.selectors.is_empty() {
            return;
        }
        let drained = std::mem::take(
            &mut *self.select_inbox.lock().unwrap_or_else(|p| p.into_inner()),
        );
        for (branch, obs) in drained {
            if let Some(sel) = self.selectors.get_mut(branch) {
                sel.observe(obs);
            }
        }
    }

    /// Flush the tail, drain the pipeline, and hand back the sink with
    /// the final entry count and the pipeline accounting.
    pub fn close(mut self) -> Result<(S, u64, WriteStats)> {
        let flushed = self.flush();
        // Always drain the group — even on error — so no task still
        // holds the sink (and a panicked task is reported, not hung).
        let t0 = Instant::now();
        let joined = self.group.join();
        self.stall += t0.elapsed();
        flushed?;
        joined?;
        self.errors.check()?;
        // Absorb the last in-flight measurements so the selection
        // summary reflects every basket that was written.
        self.drain_observations();
        let mut selection = SelectSummary::default();
        for sel in &self.selectors {
            selection.absorb(sel.summary());
        }
        let stats = WriteStats {
            serialize: Duration::from_nanos(self.counters.serialize_ns.load(Ordering::Relaxed)),
            compress: Duration::from_nanos(self.counters.compress_ns.load(Ordering::Relaxed)),
            stall: self.stall,
            baskets: self.counters.baskets.load(Ordering::Relaxed),
            sizing: self.sizer.summary(),
            selection,
        };
        let sink = Arc::try_unwrap(self.sink)
            .map_err(|_| Error::Sync("flush tasks still hold the sink".into()))?;
        Ok((sink, self.entries, stats))
    }
}

/// One branch basket's serialise → compress → store job.
struct BasketTask<S: BasketSink> {
    col: ColumnData,
    meta: BasketMeta,
    sink: Arc<S>,
    settings: Settings,
    granularity: FlushGranularity,
    recorder: Recorder,
    registry: Registry,
    /// Paged-layout page task: `run` wraps itself in a
    /// [`SpanKind::PageSeal`] span (union accounting keeps the nested
    /// serialize/compress spans from double-counting).
    page: bool,
    counters: Arc<TaskCounters>,
    errors: Arc<ErrorSlot>,
    /// Selection inbox: when per-column selection is active the stored
    /// basket reports one `(branch, Observation)` here for the producer
    /// to relay at its next flush.
    obs: Option<Arc<Mutex<Vec<(usize, Observation)>>>>,
    /// This basket's compression CPU, accumulated across block subtasks
    /// so the observation covers the whole basket.
    obs_compress_ns: AtomicU64,
    /// The cluster's budget slot: released (waking blocked producers)
    /// when the last task of the cluster drops its clone — including
    /// on unwind, so a panicked basket cannot leak admission.
    _admission: Option<Arc<ClusterGuard>>,
}

impl<S: BasketSink> BasketTask<S> {
    /// Serialise the column, then compress — whole-basket for branch
    /// granularity or single-block payloads, per-block subtasks on
    /// `group` otherwise. Infallible by construction: failures go to
    /// the shared error slot.
    fn run(mut self, group: Option<&TaskGroup>) {
        // Zone capture happens on the flush task (not the producer):
        // the min/max scan rides the same parallelism as the
        // serialise/compress work, and the column is still intact here
        // (it is cleared right after serialisation).
        self.meta.zone = crate::format::ZoneMap::from_column(&self.col);
        let seal_rec = self.recorder.clone();
        let seal_start = (self.page && seal_rec.is_enabled()).then(|| seal_rec.elapsed());
        let mut raw = compress::pool::get(self.col.byte_len());
        let ((), ser) = timed(|| self.col.encode_into(&mut raw));
        self.counters.serialize_ns.fetch_add(span_ns(ser), Ordering::Relaxed);
        self.recorder.push(SpanKind::Serialize, ser.0, ser.1);
        self.meta.raw_len = raw.len() as u32;
        self.col.clear(); // release entry memory before compression
        let ranges = compress::block_ranges(raw.len());
        let split = self.granularity == FlushGranularity::Block && ranges.len() > 1;
        match group {
            Some(g) if split => Assembly::fan_out(self, raw, ranges, g),
            _ => {
                let mut payload =
                    compress::pool::get(raw.len() / 2 + compress::HEADER_LEN);
                let ((), cmp) =
                    timed(|| compress::compress_into(self.settings, &raw, &mut payload));
                self.note_compress(cmp);
                drop(raw);
                self.store(payload);
            }
        }
        if let Some(start) = seal_start {
            seal_rec.push(SpanKind::PageSeal, start, seal_rec.elapsed());
        }
    }

    fn note_compress(&self, span: (Duration, Duration)) {
        let ns = span_ns(span);
        self.counters.compress_ns.fetch_add(ns, Ordering::Relaxed);
        self.obs_compress_ns.fetch_add(ns, Ordering::Relaxed);
        self.registry.basket_compress().record(Duration::from_nanos(ns));
        self.recorder.push(SpanKind::Compress, span.0, span.1);
    }

    fn store(&self, payload: PayloadBuf) {
        self.counters.baskets.fetch_add(1, Ordering::Relaxed);
        if let Some(inbox) = &self.obs {
            let obs = Observation {
                settings: self.settings,
                raw_len: self.meta.raw_len as u64,
                comp_len: payload.len() as u64,
                nanos: self.obs_compress_ns.load(Ordering::Relaxed),
            };
            inbox
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .push((self.meta.branch, obs));
        }
        if let Err(e) = self.sink.put_basket(self.meta, payload) {
            self.errors.set(e);
        }
    }
}

/// Shared state of one basket whose blocks compress as parallel
/// subtasks; the last block to finish assembles the container (in
/// block order, so bytes match the serial writer) and stores it.
struct Assembly<S: BasketSink> {
    task: BasketTask<S>,
    raw: PayloadBuf,
    ranges: Vec<std::ops::Range<usize>>,
    slots: Vec<Mutex<Option<PayloadBuf>>>,
    remaining: AtomicUsize,
}

impl<S: BasketSink> Assembly<S> {
    fn fan_out(
        task: BasketTask<S>,
        raw: PayloadBuf,
        ranges: Vec<std::ops::Range<usize>>,
        group: &TaskGroup,
    ) {
        let n = ranges.len();
        let asm = Arc::new(Assembly {
            task,
            raw,
            ranges,
            slots: (0..n).map(|_| Mutex::new(None)).collect(),
            remaining: AtomicUsize::new(n),
        });
        for c in 1..n {
            let asm = asm.clone();
            group.spawn(move || asm.compress_block(c));
        }
        asm.compress_block(0);
    }

    fn compress_block(&self, c: usize) {
        let range = self.ranges[c].clone();
        let chunk = &self.raw[range];
        let mut out = compress::pool::get(chunk.len() / 2 + compress::HEADER_LEN);
        let ((), cmp) = timed(|| compress::compress_into(self.task.settings, chunk, &mut out));
        self.task.note_compress(cmp);
        *self.slots[c].lock().unwrap_or_else(|p| p.into_inner()) = Some(out);
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.assemble();
        }
    }

    fn assemble(&self) {
        let mut payload = compress::pool::get(
            self.raw.len() / 2 + self.slots.len() * compress::HEADER_LEN,
        );
        for slot in &self.slots {
            let block = slot.lock().unwrap_or_else(|p| p.into_inner()).take();
            match block {
                Some(b) => payload.extend_from_slice(&b),
                None => {
                    self.task.errors.set(Error::Sync(
                        "missing compressed block in basket assembly".into(),
                    ));
                    return;
                }
            }
        }
        self.task.store(payload);
    }
}

fn span_ns(span: (Duration, Duration)) -> u64 {
    span.1.saturating_sub(span.0).as_nanos() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::serial::schema::{ColumnType, Field};
    use crate::serial::value::Value;
    use crate::tree::sink::BufferSink;

    fn schema() -> Schema {
        Schema::new(vec![Field::new("x", ColumnType::F32), Field::new("n", ColumnType::I32)])
    }

    fn config(basket: usize) -> WriterConfig {
        WriterConfig {
            basket_entries: basket,
            compression: Settings::new(Codec::Lz4r, 3),
            flush: FlushMode::Serial,
            ..Default::default()
        }
    }

    #[test]
    fn clusters_are_aligned_and_cover_all_entries() {
        let mut w = TreeWriter::new(schema(), BufferSink::new(schema()), config(100));
        for i in 0..250 {
            w.fill(vec![Value::F32(i as f32), Value::I32(i)]).unwrap();
        }
        let (sink, entries, stats) = w.close().unwrap();
        assert_eq!(entries, 250);
        assert_eq!(stats.baskets, 6); // 3 clusters x 2 branches
        let buf = sink.into_buffer(entries).unwrap();
        // 100 + 100 + 50
        for br in &buf.branches {
            let counts: Vec<u32> = br.baskets.iter().map(|b| b.n_entries).collect();
            assert_eq!(counts, vec![100, 100, 50]);
            let firsts: Vec<u64> = br.baskets.iter().map(|b| b.first_entry).collect();
            assert_eq!(firsts, vec![0, 100, 200]);
        }
    }

    #[test]
    fn fill_columns_bulk_path() {
        let mut w = TreeWriter::new(schema(), BufferSink::new(schema()), config(64));
        let block = vec![
            ColumnData::F32((0..100).map(|i| i as f32).collect()),
            ColumnData::I32((0..100).collect()),
        ];
        w.fill_columns(&block).unwrap();
        w.fill_columns(&block).unwrap();
        let (sink, entries, _) = w.close().unwrap();
        assert_eq!(entries, 200);
        let buf = sink.into_buffer(entries).unwrap();
        let total: u32 = buf.branches[0].baskets.iter().map(|b| b.n_entries).sum();
        assert_eq!(total, 200);
    }

    #[test]
    fn fill_columns_rejects_ragged_and_wrong_arity() {
        let mut w = TreeWriter::new(schema(), BufferSink::new(schema()), config(64));
        assert!(w.fill_columns(&[ColumnData::F32(vec![1.0])]).is_err());
        assert!(w
            .fill_columns(&[ColumnData::F32(vec![1.0]), ColumnData::I32(vec![1, 2])])
            .is_err());
    }

    #[test]
    fn empty_close() {
        let w = TreeWriter::new(schema(), BufferSink::new(schema()), config(10));
        let (sink, entries, stats) = w.close().unwrap();
        assert_eq!(entries, 0);
        assert_eq!(stats.baskets, 0);
        assert!(sink.into_buffer(0).unwrap().is_empty());
    }

    #[test]
    fn serial_flush_with_adaptive_knob_behaves_as_fixed() {
        // The serial path has no backpressure signal: an Adaptive
        // config must not move the cluster size, and the summary still
        // reports the (constant) size band through close().
        use crate::tree::sizer::AdaptiveConfig;
        let cfg = WriterConfig {
            sizing: ClusterSizing::Adaptive(AdaptiveConfig::around(100)),
            ..config(100)
        };
        let mut w = TreeWriter::new(schema(), BufferSink::new(schema()), cfg);
        for i in 0..350 {
            w.fill(vec![Value::F32(i as f32), Value::I32(i)]).unwrap();
        }
        assert_eq!(w.cluster_target(), 100);
        assert!(w.sizer_trace().is_empty(), "serial flush must not adapt");
        let (sink, entries, stats) = w.close().unwrap();
        assert_eq!(entries, 350);
        assert_eq!(stats.sizing.min_entries, 100);
        assert_eq!(stats.sizing.max_entries, 100);
        assert_eq!(stats.sizing.last_entries, 100);
        assert_eq!(stats.sizing.resizes(), 0);
        let buf = sink.into_buffer(entries).unwrap();
        let counts: Vec<u32> =
            buf.branches[0].baskets.iter().map(|b| b.n_entries).collect();
        assert_eq!(counts, vec![100, 100, 100, 50]);
    }

    #[test]
    fn fat_basket_splits_into_block_subtasks_and_matches_serial() {
        // A basket whose raw payload exceeds MAX_BLOCK: under block
        // granularity it compresses as per-block subtasks; the stored
        // container must byte-match the serial (whole-buffer) path.
        let n = compress::MAX_BLOCK + 4096;
        let schema = Schema::new(vec![Field::new("b", ColumnType::U8)]);
        let col = ColumnData::U8((0..n).map(|i| (i % 251) as u8).collect());
        let mk = |pool: Option<Arc<Pool>>| {
            let cfg = WriterConfig {
                basket_entries: n,
                compression: Settings::uncompressed(),
                flush: if pool.is_some() { FlushMode::Pipelined } else { FlushMode::Serial },
                granularity: FlushGranularity::Block,
                max_inflight_clusters: 2,
                ..Default::default()
            };
            let mut w = TreeWriter::new(schema.clone(), BufferSink::new(schema.clone()), cfg);
            if let Some(p) = pool {
                w = w.with_pool(p);
            }
            w.fill_columns(std::slice::from_ref(&col)).unwrap();
            let (sink, entries, _) = w.close().unwrap();
            sink.into_buffer(entries).unwrap()
        };
        let serial = mk(None);
        let piped = mk(Some(Arc::new(Pool::new(3))));
        assert_eq!(serial.branches[0].baskets.len(), 1);
        assert_eq!(
            piped.branches[0].baskets[0].bytes,
            serial.branches[0].baskets[0].bytes,
            "block-subtask container diverged from serial bytes"
        );
    }

    #[test]
    fn per_column_selection_probes_commits_and_records_settings() {
        use crate::compress::select::SelectConfig;
        let select = SelectConfig::default();
        let probe_span = select.candidates.len() * select.probe_baskets as usize;
        let cfg = WriterConfig {
            selection: CodecSelection::PerColumn(select.clone()),
            ..config(64)
        };
        let mut w = TreeWriter::new(schema(), BufferSink::new(schema()), cfg);
        let clusters = 30usize;
        for i in 0..(64 * clusters) as i32 {
            w.fill(vec![Value::F32((i % 7) as f32), Value::I32(i % 5)]).unwrap();
        }
        // Serial flush: every observation is back before the next
        // basket is issued, so both columns must have committed.
        for branch in 0..2 {
            assert!(
                w.selector_choice(branch).is_some(),
                "column {branch} did not commit after {clusters} baskets"
            );
            let trace = w.selector_trace(branch);
            assert_eq!(trace.len(), clusters);
            assert_eq!(
                trace.iter().filter(|d| d.probing).count(),
                probe_span,
                "probe round should cover every candidate"
            );
        }
        let (sink, entries, stats) = w.close().unwrap();
        assert_eq!(stats.selection.columns, 2);
        assert_eq!(stats.selection.committed, 2);
        assert_eq!(stats.selection.probes, 2 * probe_span as u64);
        // Every basket records the settings it was written with, and
        // after the probe window each branch rides its committed choice.
        let buf = sink.into_buffer(entries).unwrap();
        for br in &buf.branches {
            assert_eq!(br.baskets.len(), clusters);
            let committed = br.baskets.last().unwrap().settings;
            assert!(br.baskets[probe_span + 1..]
                .iter()
                .all(|k| k.settings == committed));
        }
    }

    #[test]
    fn paged_layout_cuts_pages_and_records_cluster_spans() {
        let cfg = WriterConfig {
            layout: Layout::Paged { page_entries: 32 },
            ..config(100)
        };
        let mut w = TreeWriter::new(schema(), BufferSink::new(schema()), cfg);
        for i in 0..250 {
            w.fill(vec![Value::F32(i as f32), Value::I32(i)]).unwrap();
        }
        let (sink, entries, _) = w.close().unwrap();
        let buf = sink.into_buffer(entries).unwrap();
        let spans: Vec<(u64, u64)> =
            buf.clusters.iter().map(|c| (c.first_entry, c.n_entries)).collect();
        assert_eq!(spans, vec![(0, 100), (100, 100), (200, 50)]);
        // 100-entry clusters cut into 32-row pages: 32+32+32+4 per full
        // cluster, 32+18 for the 50-entry tail — per branch.
        for br in &buf.branches {
            let counts: Vec<u32> = br.baskets.iter().map(|b| b.n_entries).collect();
            assert_eq!(counts, vec![32, 32, 32, 4, 32, 32, 32, 4, 32, 18]);
            let firsts: Vec<u64> = br.baskets.iter().map(|b| b.first_entry).collect();
            assert_eq!(firsts, vec![0, 32, 64, 96, 100, 132, 164, 196, 200, 232]);
            assert!(br.elems.is_empty(), "fixed-width branches have no element pages");
        }
    }

    #[test]
    fn paged_variable_length_branch_emits_paired_offset_and_element_pages() {
        let schema = Schema::new(vec![
            Field::new("x", ColumnType::F32),
            Field::new("hits", ColumnType::ListF32),
        ]);
        let cfg = WriterConfig {
            layout: Layout::Paged { page_entries: 16 },
            ..config(64)
        };
        let mut w = TreeWriter::new(schema.clone(), BufferSink::new(schema.clone()), cfg);
        for i in 0..100u32 {
            let list: Vec<f32> = (0..i % 5).map(|j| (i + j) as f32).collect();
            w.fill(vec![Value::F32(i as f32), Value::ListF32(list)]).unwrap();
        }
        let (sink, entries, _) = w.close().unwrap();
        let buf = sink.into_buffer(entries).unwrap();
        let hits = &buf.branches[1];
        assert_eq!(
            hits.elems.len(),
            hits.baskets.len(),
            "paged list branch pairs every offset page with an element page"
        );
        // Offset pages cover entries gaplessly; element pages cover the
        // flattened values gaplessly (kept 1:1 even when empty).
        let mut next_entry = 0u64;
        let mut next_elem = 0u64;
        let mut total_elems = 0u64;
        for (off, el) in hits.baskets.iter().zip(&hits.elems) {
            assert_eq!(off.first_entry, next_entry);
            next_entry += off.n_entries as u64;
            assert_eq!(el.first_entry, next_elem);
            next_elem += el.n_entries as u64;
            total_elems += el.n_entries as u64;
        }
        assert_eq!(next_entry, 100);
        let expected: u64 = (0..100u64).map(|i| i % 5).sum();
        assert_eq!(total_elems, expected);
        // The fixed-width branch stays element-page-free.
        assert!(buf.branches[0].elems.is_empty());
    }

    /// Acceptance (ISSUE 8 tentpole): pages sealed concurrently on the
    /// pool — the pipelined flush, where every page is its own
    /// serialise+compress task — must produce byte-identical baskets,
    /// element pages and cluster spans to the serial writer, across
    /// codecs and including a variable-length branch.
    #[test]
    fn paged_pipelined_flush_matches_serial_bytes_across_codecs() {
        let schema = Schema::new(vec![
            Field::new("x", ColumnType::F32),
            Field::new("n", ColumnType::I32),
            Field::new("hits", ColumnType::ListF32),
        ]);
        let rows: Vec<Row> = (0..600u32)
            .map(|i| {
                let list: Vec<f32> = (0..i % 7).map(|j| (i * 3 + j) as f32 * 0.5).collect();
                vec![Value::F32((i % 97) as f32), Value::I32(i as i32 % 13), Value::ListF32(list)]
            })
            .collect();
        for settings in [
            Settings::uncompressed(),
            Settings::new(Codec::Lz4r, 3),
            Settings::new(Codec::Rzip, 4),
        ] {
            let mk = |pool: Option<Arc<Pool>>| {
                let cfg = WriterConfig {
                    basket_entries: 128,
                    compression: settings,
                    flush: if pool.is_some() {
                        FlushMode::Pipelined
                    } else {
                        FlushMode::Serial
                    },
                    layout: Layout::Paged { page_entries: 48 },
                    max_inflight_clusters: 3,
                    ..Default::default()
                };
                let mut w =
                    TreeWriter::new(schema.clone(), BufferSink::new(schema.clone()), cfg);
                if let Some(p) = pool {
                    w = w.with_pool(p);
                }
                for r in &rows {
                    w.fill(r.clone()).unwrap();
                }
                let (sink, entries, _) = w.close().unwrap();
                sink.into_buffer(entries).unwrap()
            };
            let serial = mk(None);
            let piped = mk(Some(Arc::new(Pool::new(4))));
            assert_eq!(serial.clusters.len(), piped.clusters.len());
            for (a, b) in serial.clusters.iter().zip(&piped.clusters) {
                assert_eq!((a.first_entry, a.n_entries), (b.first_entry, b.n_entries));
            }
            for (bs, bp) in serial.branches.iter().zip(&piped.branches) {
                assert_eq!(bs.baskets.len(), bp.baskets.len());
                for (ks, kp) in bs.baskets.iter().zip(&bp.baskets) {
                    assert_eq!(ks.bytes, kp.bytes, "page bytes diverged ({settings:?})");
                    assert_eq!(ks.first_entry, kp.first_entry);
                }
                assert_eq!(bs.elems.len(), bp.elems.len());
                for (ks, kp) in bs.elems.iter().zip(&bp.elems) {
                    assert_eq!(ks.bytes, kp.bytes, "element page bytes diverged ({settings:?})");
                    assert_eq!(ks.first_entry, kp.first_entry);
                }
            }
        }
    }

    #[test]
    fn selection_output_decodes_identically_to_global() {
        use crate::compress::select::SelectConfig;
        // Whatever trace the selector takes, the decoded tree must
        // match a globally-compressed write of the same rows.
        let rows: Vec<Row> = (0..1000)
            .map(|i| vec![Value::F32((i as f32).sin()), Value::I32(i % 11)])
            .collect();
        let write = |selection: CodecSelection| {
            let cfg = WriterConfig { selection, ..config(64) };
            let mut w = TreeWriter::new(schema(), BufferSink::new(schema()), cfg);
            for r in &rows {
                w.fill(r.clone()).unwrap();
            }
            let (sink, entries, _) = w.close().unwrap();
            sink.into_buffer(entries).unwrap()
        };
        let global = write(CodecSelection::Global);
        let selected = write(CodecSelection::PerColumn(SelectConfig::default()));
        assert_eq!(global.entries, selected.entries);
        for (bg, bs) in global.branches.iter().zip(&selected.branches) {
            let raw_g: Vec<u8> = bg
                .baskets
                .iter()
                .flat_map(|k| compress::decompress(&k.bytes).unwrap())
                .collect();
            let raw_s: Vec<u8> = bs
                .baskets
                .iter()
                .flat_map(|k| compress::decompress(&k.bytes).unwrap())
                .collect();
            assert_eq!(raw_g, raw_s, "per-column selection changed decoded bytes");
        }
    }
}
