//! Adaptive cluster sizing: a per-writer feedback controller that
//! adjusts the effective `basket_entries` *between* clusters.
//!
//! A static cluster size forces one compromise on every workload
//! (Riley & Jones observe exactly this oscillation between producer
//! starvation and memory pressure in multi-threaded CMS output): tiny
//! clusters pay per-basket overhead — task spawn, admission, and the
//! codec's per-call setup (the rzip LZ77 hash table alone is a fixed
//! half-megabyte initialisation per compress call) — while huge
//! clusters starve the pool between flushes and balloon the buffered
//! tail. The pipelined writer already measures the two signals that
//! decide which side a writer is on:
//!
//! * the **stall / compress ratio** — producer wall time blocked on
//!   admission versus compression CPU burned in the window
//!   ([`crate::tree::writer::WriteStats`]); a high ratio means
//!   compression is the bottleneck and per-basket overhead is worth
//!   amortising over bigger clusters;
//! * the writer's **admission-wait feedback** from the session budget
//!   ([`crate::imt::WriterBudget::waits`]) — every wait is a cluster
//!   that found the shared in-flight budget full.
//!
//! [`ClusterSizer::observe`] consumes cumulative totals of both after
//! each flushed cluster and classifies the window as [`Signal::Grow`]
//! (waited, or stalled past `grow_stall_ratio`), [`Signal::Shrink`]
//! (no wait and the producer essentially never stalled — the pipeline
//! has slack, so cut smaller clusters and keep the pool fed sooner),
//! or [`Signal::Hold`]. Steps are ×2 / ÷2 with **hysteresis** (a
//! signal must repeat `hysteresis` windows in a row) and hard
//! **min/max clamps**, after a fixed `warmup` of windows that lets the
//! pipeline fill before the first judgement.
//!
//! **Determinism.** The chosen sizes depend on observed timing, so
//! cluster boundaries are schedule-dependent — but the mapping from
//! the *decision trace* to the output is pure: the same trace yields
//! the same cluster cuts and therefore the same bytes, and any trace
//! yields entry-identical decoded data (the equivalence property the
//! stress suite asserts). Every decision is recorded
//! ([`ClusterSizer::trace`]) so a run can be replayed or audited, and
//! [`SizerSummary`] travels up through `WriteStats` / `WriteReport`.

use std::time::Duration;

/// Cluster-size policy knob in [`crate::tree::writer::WriterConfig`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum ClusterSizing {
    /// Every cluster is `basket_entries` (the historical behaviour).
    #[default]
    Fixed,
    /// Feedback-sized clusters, starting from `basket_entries` and
    /// adjusted between clusters per [`AdaptiveConfig`]. Only the
    /// pipelined flush adapts (the serial and parallel-blocking paths
    /// have no backpressure signal and behave exactly like `Fixed`).
    Adaptive(AdaptiveConfig),
}

/// Tuning for [`ClusterSizing::Adaptive`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdaptiveConfig {
    /// Hard floor on entries per cluster.
    pub min_entries: usize,
    /// Hard ceiling on entries per cluster.
    pub max_entries: usize,
    /// Stall/compress ratio above which a window votes Grow (the
    /// producer is waiting out compression).
    pub grow_stall_ratio: f64,
    /// Stall/compress ratio below which a wait-free window votes
    /// Shrink (the pipeline has slack; smaller clusters feed the pool
    /// sooner and shrink the buffered tail).
    pub shrink_stall_ratio: f64,
    /// Consecutive same-direction windows required before a step —
    /// damping against one-off scheduling noise. Min 1 (step on every
    /// decisive window).
    pub hysteresis: u32,
    /// Initial windows observed without stepping, so judgements start
    /// only once the in-flight pipeline is primed.
    pub warmup: u32,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            min_entries: 256,
            max_entries: 65_536,
            grow_stall_ratio: 0.25,
            shrink_stall_ratio: 0.02,
            hysteresis: 2,
            warmup: 2,
        }
    }
}

impl AdaptiveConfig {
    /// Clamp band of ×8 either side of `base` (a writer that keeps the
    /// default `basket_entries` adapts within an order of magnitude).
    pub fn around(base: usize) -> Self {
        let base = base.max(1);
        AdaptiveConfig {
            min_entries: (base / 8).max(1),
            max_entries: base.saturating_mul(8),
            ..Default::default()
        }
    }
}

/// What one observation window said about the pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Signal {
    /// Admission waited or the producer stalled past the grow
    /// threshold: compression is the bottleneck, amortise it.
    Grow,
    /// No wait and essentially no stall: slack in the pipeline, cut
    /// smaller clusters.
    Shrink,
    /// In between (or warmup): keep the current size.
    Hold,
}

/// One recorded sizing decision — the unit of the replayable trace.
#[derive(Clone, Copy, Debug)]
pub struct Decision {
    /// Index of the cluster whose window was observed (0-based).
    pub cluster: u64,
    /// The window's classification.
    pub signal: Signal,
    /// Observed stall/compress ratio in the window (∞ when the window
    /// stalled but no compression completed).
    pub stall_ratio: f64,
    /// Did admission wait during the window?
    pub waited: bool,
    /// Target entries for the *next* cluster, after any step.
    pub entries: usize,
}

/// Compact sizing report carried in `WriteStats` / `WriteReport`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SizerSummary {
    /// Smallest cluster target used.
    pub min_entries: usize,
    /// Largest cluster target used.
    pub max_entries: usize,
    /// Target in effect when the writer closed.
    pub last_entries: usize,
    /// Number of ×2 steps taken.
    pub grows: u32,
    /// Number of ÷2 steps taken.
    pub shrinks: u32,
    /// Observation windows (flushed clusters) seen.
    pub clusters: u64,
}

impl SizerSummary {
    /// Total resize steps.
    pub fn resizes(&self) -> u64 {
        self.grows as u64 + self.shrinks as u64
    }
}

/// Stall deltas below this are scheduling noise, not backpressure.
const MIN_SIGNAL_STALL: Duration = Duration::from_micros(20);

/// Cap on recorded decisions: long-lived writers keep the *earliest*
/// windows (the ramp — the interesting part of a trace) and only the
/// counters beyond that, so the trace cannot grow without bound.
const MAX_TRACE: usize = 4096;

/// The per-writer controller. Constructed from the writer's config;
/// [`ClusterSizer::target`] is the entries count for the next cluster
/// cut, [`ClusterSizer::observe`] feeds one window of cumulative
/// counters back in.
#[derive(Clone, Debug)]
pub struct ClusterSizer {
    mode: ClusterSizing,
    current: usize,
    /// Signed streak: positive = consecutive Grow windows, negative =
    /// consecutive Shrink windows.
    streak: i32,
    clusters: u64,
    grows: u32,
    shrinks: u32,
    seen_min: usize,
    seen_max: usize,
    last_stall: Duration,
    last_compress: Duration,
    last_waits: u64,
    trace: Vec<Decision>,
}

impl ClusterSizer {
    /// Controller starting at `base` entries (clamped into the
    /// adaptive band when `mode` is adaptive).
    pub fn new(base: usize, mode: ClusterSizing) -> Self {
        let base = base.max(1);
        let current = match mode {
            ClusterSizing::Fixed => base,
            ClusterSizing::Adaptive(cfg) => {
                base.clamp(cfg.min_entries.max(1), cfg.max_entries.max(1))
            }
        };
        ClusterSizer {
            mode,
            current,
            streak: 0,
            clusters: 0,
            grows: 0,
            shrinks: 0,
            seen_min: current,
            seen_max: current,
            last_stall: Duration::ZERO,
            last_compress: Duration::ZERO,
            last_waits: 0,
            trace: Vec::new(),
        }
    }

    /// Entries the next cluster should hold.
    pub fn target(&self) -> usize {
        self.current
    }

    pub fn is_adaptive(&self) -> bool {
        matches!(self.mode, ClusterSizing::Adaptive(_))
    }

    /// The replayable decision trace (empty under `Fixed`). Bounded:
    /// only the first `MAX_TRACE` (4096) windows are recorded — the
    /// ramp — while [`SizerSummary`] keeps counting past the cap.
    pub fn trace(&self) -> &[Decision] {
        &self.trace
    }

    /// Feed one window: *cumulative* producer stall, *cumulative*
    /// compression CPU and the writer's *cumulative* admission-wait
    /// count after a flushed cluster. Deltas are taken internally, a
    /// signal is classified, and the target steps when the signal has
    /// repeated `hysteresis` windows (after `warmup`). No-op under
    /// [`ClusterSizing::Fixed`] beyond counting the window.
    pub fn observe(&mut self, stall: Duration, compress: Duration, waits: u64) {
        let window = self.clusters;
        self.clusters += 1;
        let ClusterSizing::Adaptive(cfg) = self.mode else {
            return;
        };
        let d_stall = stall.saturating_sub(self.last_stall);
        let d_compress = compress.saturating_sub(self.last_compress);
        let waited = waits > self.last_waits;
        self.last_stall = stall;
        self.last_compress = compress;
        self.last_waits = waits;

        let stall_real = if d_stall < MIN_SIGNAL_STALL { Duration::ZERO } else { d_stall };
        let ratio = if d_compress.is_zero() {
            if stall_real.is_zero() {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            stall_real.as_secs_f64() / d_compress.as_secs_f64()
        };
        let signal = if window < cfg.warmup as u64 {
            Signal::Hold
        } else if waited || ratio > cfg.grow_stall_ratio {
            Signal::Grow
        } else if !d_compress.is_zero() && ratio < cfg.shrink_stall_ratio {
            Signal::Shrink
        } else {
            Signal::Hold
        };

        match signal {
            Signal::Grow => self.streak = self.streak.max(0) + 1,
            Signal::Shrink => self.streak = self.streak.min(0) - 1,
            Signal::Hold => self.streak = 0,
        }
        let h = cfg.hysteresis.max(1) as i32;
        if self.streak >= h {
            let next = self.current.saturating_mul(2).min(cfg.max_entries.max(1));
            if next != self.current {
                self.grows += 1;
                self.current = next;
            }
            self.streak = 0;
        } else if self.streak <= -h {
            let next = (self.current / 2).max(cfg.min_entries.max(1)).max(1);
            if next != self.current {
                self.shrinks += 1;
                self.current = next;
            }
            self.streak = 0;
        }
        self.seen_min = self.seen_min.min(self.current);
        self.seen_max = self.seen_max.max(self.current);
        if self.trace.len() < MAX_TRACE {
            self.trace.push(Decision {
                cluster: window,
                signal,
                stall_ratio: ratio,
                waited,
                entries: self.current,
            });
        }
    }

    pub fn summary(&self) -> SizerSummary {
        SizerSummary {
            min_entries: self.seen_min,
            max_entries: self.seen_max,
            last_entries: self.current,
            grows: self.grows,
            shrinks: self.shrinks,
            clusters: self.clusters,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    fn adaptive(min: usize, max: usize) -> ClusterSizer {
        ClusterSizer::new(
            min,
            ClusterSizing::Adaptive(AdaptiveConfig {
                min_entries: min,
                max_entries: max,
                hysteresis: 2,
                warmup: 0,
                ..Default::default()
            }),
        )
    }

    #[test]
    fn fixed_never_moves() {
        let mut s = ClusterSizer::new(100, ClusterSizing::Fixed);
        for i in 0..10u64 {
            s.observe(ms(50 * (i + 1)), ms(i + 1), i);
        }
        assert_eq!(s.target(), 100);
        assert!(s.trace().is_empty());
        let sum = s.summary();
        assert_eq!((sum.min_entries, sum.max_entries, sum.last_entries), (100, 100, 100));
        assert_eq!(sum.resizes(), 0);
        assert_eq!(sum.clusters, 10);
    }

    #[test]
    fn sustained_waits_grow_with_hysteresis() {
        let mut s = adaptive(64, 1024);
        // One wait is not enough (hysteresis 2)...
        s.observe(ms(10), ms(10), 1);
        assert_eq!(s.target(), 64);
        // ...the second consecutive wait steps ×2.
        s.observe(ms(20), ms(20), 2);
        assert_eq!(s.target(), 128);
        // Two more waits: ×2 again.
        s.observe(ms(30), ms(30), 3);
        s.observe(ms(40), ms(40), 4);
        assert_eq!(s.target(), 256);
        assert_eq!(s.summary().grows, 2);
        assert_eq!(s.trace().len(), 4);
        assert!(s.trace().iter().all(|d| d.signal == Signal::Grow && d.waited));
    }

    #[test]
    fn growth_clamps_at_max() {
        let mut s = adaptive(64, 256);
        for i in 1..20u64 {
            s.observe(ms(10 * i), ms(10 * i), i);
        }
        assert_eq!(s.target(), 256);
        let sum = s.summary();
        assert_eq!(sum.max_entries, 256);
        assert_eq!(sum.grows, 2, "64 -> 128 -> 256, then clamped");
    }

    #[test]
    fn idle_producer_shrinks_to_min() {
        let cfg = AdaptiveConfig {
            min_entries: 64,
            max_entries: 4096,
            hysteresis: 2,
            warmup: 0,
            ..Default::default()
        };
        let mut s = ClusterSizer::new(1024, ClusterSizing::Adaptive(cfg));
        for i in 1..20u64 {
            // No waits, zero stall, real compression: pure slack.
            s.observe(Duration::ZERO, ms(10 * i), 0);
        }
        assert_eq!(s.target(), 64);
        assert!(s.summary().shrinks >= 4, "1024 -> 512 -> 256 -> 128 -> 64");
        assert_eq!(s.summary().min_entries, 64);
    }

    #[test]
    fn hold_band_is_stable_and_resets_streaks() {
        let cfg = AdaptiveConfig {
            min_entries: 64,
            max_entries: 4096,
            grow_stall_ratio: 0.5,
            shrink_stall_ratio: 0.05,
            hysteresis: 2,
            warmup: 0,
        };
        let mut s = ClusterSizer::new(512, ClusterSizing::Adaptive(cfg));
        // Ratio 0.2 sits between the thresholds: Hold forever.
        for i in 1..10u64 {
            s.observe(ms(2 * i), ms(10 * i), 0);
        }
        assert_eq!(s.target(), 512);
        // A single Grow window between Holds never accumulates a streak.
        s.observe(ms(18 + 2 * 9), ms(10 * 10), 1);
        s.observe(ms(18 + 2 * 9 + 2), ms(10 * 11), 1);
        // (second window: no new wait count change? waits stayed 1 ->
        // waited=false, ratio low -> Shrink/Hold resets the streak)
        assert_eq!(s.target(), 512, "no two consecutive grow windows");
    }

    #[test]
    fn warmup_windows_never_step() {
        let cfg = AdaptiveConfig { min_entries: 64, max_entries: 1024, warmup: 3, hysteresis: 1, ..Default::default() };
        let mut s = ClusterSizer::new(64, ClusterSizing::Adaptive(cfg));
        for i in 1..=3u64 {
            s.observe(ms(10 * i), ms(10 * i), i);
            if i < 4 {
                // warmup windows are recorded as Hold
                assert_eq!(s.trace().last().unwrap().signal, Signal::Hold);
            }
        }
        assert_eq!(s.target(), 64);
        s.observe(ms(40), ms(40), 4);
        assert_eq!(s.target(), 128, "first post-warmup wait steps (hysteresis 1)");
    }

    #[test]
    fn tiny_stall_deltas_are_noise_not_growth() {
        let mut s = adaptive(64, 1024);
        for i in 1..10u64 {
            // 5 µs of stall per window with real compression: below the
            // noise floor, and no waits -> shrink pressure, not growth.
            s.observe(Duration::from_micros(5 * i), ms(10 * i), 0);
        }
        assert_eq!(s.target(), 64, "already at the floor");
        assert_eq!(s.summary().grows, 0, "sub-floor stall must never read as backpressure");
    }

    #[test]
    fn start_size_clamps_into_band() {
        let s = ClusterSizer::new(
            1_000_000,
            ClusterSizing::Adaptive(AdaptiveConfig { min_entries: 32, max_entries: 2048, ..Default::default() }),
        );
        assert_eq!(s.target(), 2048);
        let s = ClusterSizer::new(
            1,
            ClusterSizing::Adaptive(AdaptiveConfig { min_entries: 32, max_entries: 2048, ..Default::default() }),
        );
        assert_eq!(s.target(), 32);
    }

    #[test]
    fn around_builds_a_band_about_the_base() {
        let cfg = AdaptiveConfig::around(4096);
        assert_eq!(cfg.min_entries, 512);
        assert_eq!(cfg.max_entries, 32_768);
        let tiny = AdaptiveConfig::around(2);
        assert_eq!(tiny.min_entries, 1);
    }
}
