//! The cluster prefetcher: coalesced window fetches ahead of the
//! consumer, per-basket decode tasks on the IMT pool, and an in-order
//! streaming consumption API.
//!
//! [`ClusterStream`] walks a tree's cluster list ahead of its
//! consumer. For every in-flight cluster it holds one slot of the
//! session's shared **read budget** (fair-share admission across
//! readers, exactly like writers on the write budget — except that
//! read admission never parks: read-ahead degrades when the budget is
//! full, and the consumer-demanded head window proceeds unbudgeted,
//! since a prefetched slot can only be freed by its own consumer and
//! parking could deadlock a thread on its sibling streams), issues the
//! cluster's **coalesced fetches** (one `read_at` per
//! [`super::plan::FetchRange`] — TTreeCache's one-vectored-read-per-
//! window), CRC-checks each basket against the directory, and spawns
//! one **decompress + deserialise task per basket** into the session's
//! completion domain, so decode of cluster *k* overlaps the fetch of
//! cluster *k+1..k+w*. Decoded clusters wait in a bounded cache — one
//! budget slot each — and are handed out strictly **in order** by
//! [`ClusterStream::next`]; consuming a cluster releases its slot
//! (in-order eviction), so resident memory never exceeds the window.
//!
//! The window `w` is governed by [`super::window::WindowController`] —
//! the write sizer's grow/shrink/hysteresis/trace controller fed with
//! consumer fetch-stall vs decode throughput: slow storage grows the
//! window, fast storage keeps it (and memory) minimal.
//!
//! All scratch — coalesced fetch buffers and per-basket decompression
//! targets — comes from [`crate::compress::pool`]; steady-state
//! streaming allocates only the decoded columns.
//!
//! **Unreliable storage** (ISSUE 6): every window is fetched with one
//! [`crate::storage::Backend::read_scatter`] call carrying
//! [`crate::storage::IoHints`] — head priority for the window the
//! consumer is blocked on, read-ahead for speculation — so a
//! [`crate::storage::resilient::ResilientBackend`] underneath can
//! retry, hedge, and shed with full knowledge of what is urgent. When
//! the backend reports [`crate::storage::BackendHealth::Degraded`]
//! (circuit breaker open), the pump stops speculating and fetches
//! head-only; a read-ahead window the breaker *shed* mid-flight is
//! transparently refetched inline at head priority when the consumer
//! reaches it. Both paths count into
//! [`PrefetchStats::degraded_windows`] — the stream itself never
//! surfaces a [`crate::error::Error::Shed`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::compress;
use crate::error::{Error, Result};
use crate::format::reader::FileReader;
use crate::imt::{ClusterGuard, TaskGroup};
use crate::metrics::{HistSnapshot, Histogram, Recorder, Registry, SpanKind};
use crate::serial::column::ColumnData;
use crate::serial::schema::ColumnType;
use crate::session::{ReaderRegistration, Session, SessionConfig};
use crate::storage::{BackendHealth, IoHints, ReadPriority, ResilienceStats};
use crate::tree::reader::TreeReader;
use crate::tree::sizer::{Decision, SizerSummary};

use super::plan::{ClusterPlan, ClusterWindow, PlannedBasket};
use super::window::{WindowController, WindowPolicy};

/// Streaming-read options.
#[derive(Clone, Debug)]
pub struct PrefetchOptions {
    /// Branch indices to stream (None = all), selection order = output
    /// column order.
    pub branches: Option<Vec<usize>>,
    /// Read-ahead policy (default: adaptive window).
    pub window: WindowPolicy,
    /// Max byte gap between stored baskets merged into one device
    /// fetch; slack bytes are read and discarded (far cheaper than a
    /// second seek on the devices that matter). Acts as a *floor*: a
    /// backend that reports a [`crate::storage::CostHint`] raises the
    /// effective gap via [`super::plan::adaptive_coalesce_gap`]
    /// (seek-dominated devices coalesce more aggressively); backends
    /// with no cost estimate use this value unchanged.
    pub coalesce_gap: u32,
    /// Range predicate pushed below the fetch plan: pages whose zone
    /// map (wire v4) provably excludes every matching row are never
    /// fetched. Pruning is conservative — surviving clusters may still
    /// hold non-matching rows (and zone-less v1–v3 files prune
    /// nothing), so exact row filtering stays the consumer's job (see
    /// [`crate::framework::chain::Chain::scan_where`]).
    pub predicate: Option<super::plan::Predicate>,
}

impl Default for PrefetchOptions {
    fn default() -> Self {
        PrefetchOptions {
            branches: None,
            window: WindowPolicy::default(),
            coalesce_gap: super::plan::DEFAULT_COALESCE_GAP,
            predicate: None,
        }
    }
}

impl PrefetchOptions {
    /// Convenience: a fixed window of `k` clusters.
    pub fn fixed(k: usize) -> Self {
        PrefetchOptions { window: WindowPolicy::Fixed(k), ..Default::default() }
    }
}

/// One decoded cluster, handed out in tree order.
#[derive(Clone, Debug, PartialEq)]
pub struct DecodedCluster {
    /// Cluster index (0-based, consecutive).
    pub index: usize,
    /// First entry the cluster covers (lead-branch cut).
    pub first_entry: u64,
    /// Entries the cluster covers on the lead branch.
    pub entries: u64,
    /// One decoded column chunk per selected branch, in selection
    /// order. Equal lengths for cluster-aligned trees (everything the
    /// tree writer produces); concatenating across clusters rebuilds
    /// every column in entry order either way.
    pub columns: Vec<ColumnData>,
}

/// Accounting for one stream ([`ClusterStream::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefetchStats {
    /// Clusters delivered to the consumer (error slots the cursor
    /// skipped over are not counted).
    pub clusters: u64,
    /// Baskets consumed so far — the device reads a per-basket reader
    /// would have issued for the same data.
    pub baskets: u64,
    /// Coalesced device fetches behind the *consumed* clusters — the
    /// same windows `baskets` counts, so [`Self::coalescing_factor`]
    /// is exact at any point mid-stream (read-ahead fetches still in
    /// flight are not mixed in).
    pub device_reads: u64,
    /// Stored (compressed) bytes consumed.
    pub stored_bytes: u64,
    /// Stored bytes the whole plan selects — what this stream will
    /// fetch end to end under its branch projection.
    pub bytes_selected: u64,
    /// Stored bytes of unselected branches the projection never
    /// fetches (projection pushdown's saving over a full read).
    pub bytes_skipped: u64,
    /// Selected pages a pushed-down predicate's zone maps excluded
    /// from the plan (element pages of pruned pairs count too).
    pub pages_pruned: u64,
    /// Stored bytes those pruned pages would have fetched — predicate
    /// pushdown's saving *below* the projection:
    /// `bytes_selected + bytes_pruned + bytes_skipped` partition the
    /// tree's stored bytes.
    pub bytes_pruned: u64,
    /// Consumer wall time spent waiting on a not-yet-ready cluster —
    /// the exposed storage latency the window exists to hide.
    pub fetch_stall: Duration,
    /// Device fetch wall time summed over fetch tasks.
    pub fetch_time: Duration,
    /// Decompress + deserialise CPU summed over decode tasks.
    pub decode_time: Duration,
    /// Distinct windows whose admission the session budget denied
    /// (each window counts once, however many pump retries saw the
    /// budget full; the prefetcher never blocks).
    pub admission_denials: u64,
    /// Backend retry attempts behind this stream's reads — nonzero
    /// only over a [`crate::storage::resilient::ResilientBackend`].
    /// Counted as a backend-counter delta since the stream opened, so
    /// concurrent streams on the *same* backend see each other's
    /// traffic; isolate the backend to attribute exactly.
    pub retries: u64,
    /// Hedged duplicate reads the backend launched (same delta
    /// semantics as [`PrefetchStats::retries`]).
    pub hedges: u64,
    /// Hedges that beat their primary read.
    pub hedge_wins: u64,
    /// Read attempts that missed their per-request deadline.
    pub deadline_misses: u64,
    /// Windows that degraded: submitted head-only because the backend
    /// reported itself [`crate::storage::BackendHealth::Degraded`], or
    /// shed mid-flight by the circuit breaker and refetched inline at
    /// head priority. Per-stream exact (not a backend delta).
    pub degraded_windows: u64,
    /// Window-controller band + step counts (units: clusters).
    pub window: SizerSummary,
}

impl PrefetchStats {
    /// Device-read reduction from coalescing (baskets per issued
    /// fetch); 1.0 when nothing coalesced, 0.0 before any fetch.
    pub fn coalescing_factor(&self) -> f64 {
        if self.device_reads == 0 {
            return 0.0;
        }
        self.baskets as f64 / self.device_reads as f64
    }
}

/// One in-flight cluster's shared slot: decoded parts land here, the
/// budget guard is held until the consumer takes the cluster.
struct SlotState {
    parts: Vec<Option<ColumnData>>,
    /// Decode results still outstanding (0 = ready).
    remaining: usize,
    err: Option<Error>,
    /// Read-budget slot, released the moment the consumer takes the
    /// cluster (or when an abandoned slot drops).
    guard: Option<ClusterGuard>,
    /// When the window was submitted — start of its latency clock.
    submitted: Instant,
}

/// State shared between the consumer and the fetch/decode tasks.
struct Shared {
    slots: Mutex<HashMap<usize, SlotState>>,
    fetch_nanos: AtomicU64,
    decode_nanos: AtomicU64,
    /// Completed submit→decoded latency per non-empty window — the
    /// log-bucketed distribution whose tail the hedged-read
    /// experiment measures ([`ClusterStream::window_latency`]).
    window_hist: Histogram,
    /// Session recorder (disabled = one branch per record) — fetch,
    /// scatter-read and decode tasks emit spans when tracing is on.
    recorder: Recorder,
    /// Session registry: window-latency and device-read histograms.
    registry: Registry,
}

impl Shared {
    fn is_ready(&self, idx: usize) -> bool {
        let slots = self.slots.lock().unwrap_or_else(|p| p.into_inner());
        slots.get(&idx).map(|s| s.remaining == 0 || s.err.is_some()).unwrap_or(false)
    }
}

/// Record a window-level failure (failed/short fetch, bad checksum):
/// the slot becomes ready-with-error; decode tasks already in flight
/// for it become no-ops once the consumer removes the slot.
fn fail_slot(shared: &Shared, idx: usize, err: Error) {
    let mut slots = shared.slots.lock().unwrap_or_else(|p| p.into_inner());
    if let Some(slot) = slots.get_mut(&idx) {
        if slot.err.is_none() {
            slot.err = Some(err);
        }
    }
}

/// Land one decoded basket (or its error) in the slot. The last part
/// to land stamps the window's submit→decoded latency.
fn finish_part(shared: &Shared, idx: usize, part: usize, result: Result<ColumnData>) {
    let latency = {
        let mut slots = shared.slots.lock().unwrap_or_else(|p| p.into_inner());
        let Some(slot) = slots.get_mut(&idx) else { return };
        match result {
            Ok(col) => slot.parts[part] = Some(col),
            Err(e) => {
                if slot.err.is_none() {
                    slot.err = Some(e);
                }
            }
        }
        slot.remaining = slot.remaining.saturating_sub(1);
        if slot.remaining == 0 && slot.err.is_none() {
            Some(slot.submitted.elapsed())
        } else {
            None
        }
    };
    if let Some(lat) = latency {
        shared.window_hist.record(lat);
        shared.registry.window_latency().record(lat);
    }
}

/// The fetch task for one cluster window: issue the coalesced reads
/// as one scatter batch, CRC-check each basket, spawn one decode task
/// per basket into the same group. Runs on the pool, so window
/// `k+1`'s fetch overlaps window `k`'s decode.
///
/// The whole window travels in a single
/// [`crate::storage::Backend::read_scatter`] call so the fetch either
/// lands completely or fails as a unit — in particular, a window the
/// circuit breaker sheds fails *before any decode task is spawned*,
/// which is what lets the consumer safely re-arm the slot and refetch
/// it inline at head priority.
fn fetch_window(
    file: &Arc<FileReader>,
    window: &ClusterWindow,
    shared: &Arc<Shared>,
    group: &TaskGroup,
    idx: usize,
    hints: IoHints,
) {
    let backend = file.backend();
    let fetch_start = shared.recorder.is_enabled().then(|| shared.recorder.elapsed());
    let t0 = Instant::now();
    let mut bufs = Vec::with_capacity(window.fetches.len());
    for range in &window.fetches {
        let mut buf = compress::pool::get(range.len);
        buf.resize(range.len, 0);
        bufs.push(buf);
    }
    {
        let mut ranges: Vec<(u64, &mut [u8])> = window
            .fetches
            .iter()
            .zip(bufs.iter_mut())
            .map(|(r, b)| (r.offset, b.as_mut_slice()))
            .collect();
        let read_start =
            shared.recorder.is_enabled().then(|| shared.recorder.elapsed());
        let rt0 = Instant::now();
        let result = backend.read_scatter(&mut ranges, hints);
        shared.registry.device_read().record(rt0.elapsed());
        if let Some(start) = read_start {
            shared.recorder.push(SpanKind::ScatterRead, start, shared.recorder.elapsed());
        }
        if let Err(e) = result {
            fail_slot(shared, idx, e);
            return;
        }
    }
    shared.fetch_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    if let Some(start) = fetch_start {
        shared.recorder.push(SpanKind::Fetch, start, shared.recorder.elapsed());
    }
    for (range, buf) in window.fetches.iter().zip(bufs) {
        // The coalesced buffer is shared by the range's decode tasks
        // and returns to the pool when the last of them drops it.
        let buf = Arc::new(buf);
        for &(bi, within) in &range.parts {
            let pb = window.baskets[bi];
            let end = within + pb.info.comp_len as usize;
            if let Err(e) =
                crate::format::reader::verify_basket_crc(&pb.info, &buf[within..end])
            {
                fail_slot(shared, idx, e);
                return;
            }
            // Paged list branch: the paired element page sits directly
            // after the offset page inside the same coalesced span
            // (the v3 adjacency invariant) — verify it here too, then
            // decode the pair as one task.
            if let Some(el) = pb.elem {
                let el_end = end + el.comp_len as usize;
                if let Err(e) =
                    crate::format::reader::verify_basket_crc(&el, &buf[end..el_end])
                {
                    fail_slot(shared, idx, e);
                    return;
                }
                let shared = shared.clone();
                let buf = buf.clone();
                group.spawn(move || {
                    let dec_start =
                        shared.recorder.is_enabled().then(|| shared.recorder.elapsed());
                    let t0 = Instant::now();
                    let result = crate::tree::reader::decode_page_pair(
                        &pb.info,
                        &buf[within..end],
                        &el,
                        &buf[end..el_end],
                    );
                    shared
                        .decode_nanos
                        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    if let Some(start) = dec_start {
                        shared.recorder.push(
                            SpanKind::Decompress,
                            start,
                            shared.recorder.elapsed(),
                        );
                    }
                    finish_part(&shared, idx, bi, result);
                });
                continue;
            }
            let shared = shared.clone();
            let buf = buf.clone();
            group.spawn(move || {
                let dec_start =
                    shared.recorder.is_enabled().then(|| shared.recorder.elapsed());
                let t0 = Instant::now();
                let result = crate::tree::reader::decode_basket_bytes(
                    pb.ty,
                    &pb.info,
                    &buf[within..end],
                );
                shared.decode_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                if let Some(start) = dec_start {
                    shared.recorder.push(
                        SpanKind::Decompress,
                        start,
                        shared.recorder.elapsed(),
                    );
                }
                finish_part(&shared, idx, bi, result);
            });
        }
    }
}

/// The streaming reader: prefetched, coalesced, in-order cluster
/// consumption. Construct via [`TreeReader::stream`] /
/// [`TreeReader::stream_in_session`] (or [`ClusterStream::open`]).
pub struct ClusterStream {
    file: Arc<FileReader>,
    plan: Arc<ClusterPlan>,
    slot_types: Vec<ColumnType>,
    shared: Arc<Shared>,
    group: TaskGroup,
    reg: ReaderRegistration,
    controller: WindowController,
    /// Next cluster index to submit a fetch for.
    next_submit: usize,
    /// Next cluster index the consumer will receive.
    next_consume: usize,
    /// Cumulative consumer wait on not-ready clusters.
    stall: Duration,
    /// Clusters actually handed to the consumer (`next_consume` also
    /// advances past error slots and must not be reported).
    delivered: u64,
    consumed_baskets: u64,
    consumed_fetches: u64,
    consumed_stored: u64,
    /// Distinct windows whose admission the budget denied (diagnostic;
    /// see [`PrefetchStats::admission_denials`]).
    admission_denials: u64,
    /// Last window index counted as denied — pump() retries the same
    /// frontier window every call, and a sustained denial must count
    /// once, not once per retry.
    last_denied: Option<usize>,
    /// Windows submitted head-only under a degraded backend, plus
    /// windows shed mid-flight and refetched inline.
    degraded_windows: u64,
    /// Backend resilience counters at open — [`ClusterStream::stats`]
    /// reports the delta.
    resil0: Option<ResilienceStats>,
    /// Fused after the first error: a failed stream keeps failing
    /// instead of silently yielding clusters past a hole.
    failed: bool,
}

impl ClusterStream {
    /// Stream `reader` through a **private** single-reader session on
    /// the global IMT pool (serial inline execution while IMT is off).
    pub fn open(reader: &TreeReader, opts: &PrefetchOptions) -> Result<ClusterStream> {
        let session = Session::new(SessionConfig {
            max_inflight_read_windows: opts.window.max_window(),
            ..Default::default()
        });
        ClusterStream::open_in_session(reader, opts, &session)
    }

    /// Stream `reader` as one member of a shared [`Session`]: fetch
    /// and decode tasks run in the session's completion domain, and
    /// read-ahead admission draws from the session's shared read
    /// budget alongside the job's other streams.
    pub fn open_in_session(
        reader: &TreeReader,
        opts: &PrefetchOptions,
        session: &Session,
    ) -> Result<ClusterStream> {
        let meta = reader.meta();
        let selection: Vec<usize> = match &opts.branches {
            Some(v) => v.clone(),
            None => (0..meta.branches.len()).collect(),
        };
        // Devices that expose a cost model raise the coalesce gap to
        // their seek-equivalent byte count; the requested gap is the
        // floor, and cost-blind backends (mem, plain files) use it
        // unchanged.
        let gap = match reader.file().backend().cost_hint() {
            Some(h) => {
                opts.coalesce_gap.max(super::plan::adaptive_coalesce_gap(Some(h)))
            }
            None => opts.coalesce_gap,
        };
        let plan =
            ClusterPlan::build_filtered(meta, &selection, gap, opts.predicate.as_ref())?;
        if plan.pages_pruned > 0 {
            // Zero-width mark: zone maps excluded pages from the plan.
            session.recorder().mark(SpanKind::ZonePrune);
        }
        let slot_types: Vec<ColumnType> =
            selection.iter().map(|&b| meta.branches[b].ty).collect();
        let controller = WindowController::new(opts.window);
        let reg = session.register_reader(controller.max_window());
        let resil0 = reader.file().backend().resilience();
        Ok(ClusterStream {
            file: reader.file().clone(),
            plan: Arc::new(plan),
            slot_types,
            shared: Arc::new(Shared {
                slots: Mutex::new(HashMap::new()),
                fetch_nanos: AtomicU64::new(0),
                decode_nanos: AtomicU64::new(0),
                window_hist: Histogram::new(),
                recorder: session.recorder().clone(),
                registry: session.metrics().clone(),
            }),
            group: session.task_group(),
            reg,
            controller,
            next_submit: 0,
            next_consume: 0,
            stall: Duration::ZERO,
            delivered: 0,
            consumed_baskets: 0,
            consumed_fetches: 0,
            consumed_stored: 0,
            admission_denials: 0,
            last_denied: None,
            degraded_windows: 0,
            resil0,
            failed: false,
        })
    }

    /// Columns each [`DecodedCluster`] carries.
    pub fn n_columns(&self) -> usize {
        self.slot_types.len()
    }

    /// Clusters the stream will yield in total.
    pub fn n_clusters(&self) -> usize {
        self.plan.windows.len()
    }

    /// Start prefetching now, without consuming anything: submit
    /// fetches up to the current window target. Opening a stream is
    /// lazy (the first fetch is issued by the first [`Self::next`]);
    /// a chain primes its *next* file's stream while the current
    /// file's tail decodes, so the first cross-file window is already
    /// in flight when the boundary is crossed — no inter-file stall.
    /// Idempotent and cheap once the window is full.
    pub fn prime(&mut self) {
        if !self.failed {
            self.pump();
        }
    }

    /// Submit fetches up to the current window target. Admission is
    /// **never blocking** on the read path: a prefetched slot can only
    /// be released by a `next()` call on the stream that holds it, so
    /// a consumer driving several streams from one thread could
    /// deadlock on its own siblings if admission parked. Instead,
    /// read-ahead beyond the head cluster simply degrades (the window
    /// shrinks to what the fair share admits), and the head cluster —
    /// which the consumer is synchronously demanding and will
    /// materialise immediately — proceeds *unbudgeted* when the budget
    /// is exhausted, bounding memory at `limit + one window per
    /// stream`.
    fn pump(&mut self) {
        // A degraded backend (circuit breaker open / half-open) sheds
        // read-ahead anyway — stop speculating up front, fetch only
        // the head window the consumer is blocked on, and count it.
        // The window re-opens by itself the moment health recovers.
        let degraded =
            self.file.backend().health() == BackendHealth::Degraded;
        let target = if degraded { 1 } else { self.controller.target().max(1) };
        while self.next_submit < self.plan.windows.len()
            && self.next_submit - self.next_consume < target
        {
            let head = self.next_submit == self.next_consume;
            let guard = match self.reg.try_acquire() {
                Some(g) => Some(g),
                denied => {
                    if self.last_denied != Some(self.next_submit) {
                        self.admission_denials += 1;
                        self.last_denied = Some(self.next_submit);
                    }
                    if head {
                        denied // consumer-demanded: proceed unbudgeted
                    } else {
                        break; // read-ahead degrades instead of parking
                    }
                }
            };
            if degraded {
                self.degraded_windows += 1;
            }
            self.submit(self.next_submit, guard);
            self.next_submit += 1;
        }
    }

    fn submit(&mut self, idx: usize, guard: Option<ClusterGuard>) {
        let n_baskets = self.plan.windows[idx].baskets.len();
        {
            let mut slots = self.shared.slots.lock().unwrap_or_else(|p| p.into_inner());
            slots.insert(
                idx,
                SlotState {
                    parts: (0..n_baskets).map(|_| None).collect(),
                    remaining: n_baskets,
                    err: None,
                    guard,
                    submitted: Instant::now(),
                },
            );
        }
        if n_baskets == 0 {
            return; // ready immediately (degenerate empty window)
        }
        // The consumer is (about to be) blocked on the head window;
        // everything past it is speculation the backend may shed.
        let hints = IoHints {
            priority: if idx == self.next_consume {
                ReadPriority::Head
            } else {
                ReadPriority::ReadAhead
            },
            deadline: None,
        };
        let shared = self.shared.clone();
        let file = self.file.clone();
        let group = self.group.clone();
        let plan = self.plan.clone();
        self.group.spawn(move || {
            fetch_window(&file, &plan.windows[idx], &shared, &group, idx, hints);
        });
    }

    /// The next decoded cluster in tree order, or `None` past the end.
    /// The consumer's wait on a not-yet-ready cluster is accounted as
    /// fetch stall and fed to the window controller. **Fused on
    /// error**: after the first `Err`, every subsequent call errors
    /// too — a stream can never silently resume past a hole in the
    /// entry range.
    pub fn next(&mut self) -> Result<Option<DecodedCluster>> {
        if self.failed {
            return Err(Error::Sync(
                "prefetch: stream already failed; clusters past the error are \
                 unavailable"
                    .into(),
            ));
        }
        match self.next_inner() {
            Err(e) => {
                self.failed = true;
                Err(e)
            }
            ok => ok,
        }
    }

    fn next_inner(&mut self) -> Result<Option<DecodedCluster>> {
        let idx = self.next_consume;
        let mut columns: Vec<ColumnData> =
            self.slot_types.iter().map(|&ty| ColumnData::new(ty)).collect();
        if !self.consume_next(|pb, part| {
            if columns[pb.slot].is_empty() {
                // Move the first (for aligned trees: the only) part
                // into its slot instead of copying it in.
                columns[pb.slot] = part;
                Ok(())
            } else {
                columns[pb.slot].append(&part)
            }
        })? {
            return Ok(None);
        }
        let window = &self.plan.windows[idx];
        Ok(Some(DecodedCluster {
            index: idx,
            first_entry: window.first_entry,
            entries: window.entries,
            columns,
        }))
    }

    /// Consumption core shared by [`ClusterStream::next`] and
    /// [`ClusterStream::read_all_columns`]: wait for the head cluster,
    /// release its budget slot, surface its error, then hand each
    /// decoded basket (with its plan entry) to `sink` exactly once,
    /// in window order. Returns `false` past the end of the tree.
    fn consume_next(
        &mut self,
        mut sink: impl FnMut(&PlannedBasket, ColumnData) -> Result<()>,
    ) -> Result<bool> {
        if self.next_consume >= self.plan.windows.len() {
            return Ok(false);
        }
        self.pump();
        let idx = self.next_consume;
        let mut recovered = false;
        let mut slot = loop {
            let t0 = Instant::now();
            if !self.shared.is_ready(idx) {
                if let Some(pool) = self.group.bound_pool() {
                    // Help execute fetch/decode jobs while waiting; task
                    // completions wake this parked waiter. The *group's*
                    // pool is the one the jobs run on — a lazily-bound
                    // global session could have rebound since open(). A
                    // panicked task can never deliver its basket, so the
                    // wait also ends once the group drained with a panic
                    // recorded — surfaced as Sync below, never a hang.
                    let shared = self.shared.clone();
                    let group = self.group.clone();
                    pool.wait_until(&|| {
                        shared.is_ready(idx) || (group.panicked() && group.pending() == 0)
                    });
                }
                // Without a bound pool, tasks ran inline during pump()
                // and the slot is necessarily ready.
            }
            self.stall += t0.elapsed();
            if !self.shared.is_ready(idx) {
                // A task died without delivering: drop the slot (its
                // budget guard releases) and surface the failure.
                let mut slots =
                    self.shared.slots.lock().unwrap_or_else(|p| p.into_inner());
                slots.remove(&idx);
                drop(slots);
                self.next_consume += 1;
                return Err(Error::Sync(
                    "prefetch: a fetch/decode task panicked without delivering its \
                     window"
                        .into(),
                ));
            }

            let mut slot = {
                let mut slots =
                    self.shared.slots.lock().unwrap_or_else(|p| p.into_inner());
                slots.remove(&idx).ok_or_else(|| {
                    Error::Sync("prefetch: ready cluster slot disappeared".into())
                })?
            };
            // A shed window is not a failure: the breaker refused the
            // *speculative* fetch, and now the consumer actually needs
            // it. Re-arm the slot and refetch inline at head priority
            // (which the breaker never sheds). Shedding happens at the
            // scatter call, before any decode task was spawned, so no
            // stale task can land parts on the re-armed slot. One
            // recovery per window — a head-priority Shed is a real
            // backend bug and surfaces as the error it is.
            if !recovered && matches!(slot.err, Some(Error::Shed(_))) {
                recovered = true;
                self.degraded_windows += 1;
                let n_baskets = self.plan.windows[idx].baskets.len();
                {
                    let mut slots =
                        self.shared.slots.lock().unwrap_or_else(|p| p.into_inner());
                    slots.insert(
                        idx,
                        SlotState {
                            parts: (0..n_baskets).map(|_| None).collect(),
                            remaining: n_baskets,
                            err: None,
                            guard: slot.guard.take(),
                            submitted: slot.submitted,
                        },
                    );
                }
                fetch_window(
                    &self.file,
                    &self.plan.windows[idx],
                    &self.shared,
                    &self.group,
                    idx,
                    IoHints::default(),
                );
                continue;
            }
            break slot;
        };
        self.next_consume += 1;
        // The window is consumed: release its budget slot *now*, not
        // when the local `slot` drops at the end of this call — the
        // tail pump() below must see the freed capacity so a cap-1
        // policy (WindowPolicy::None / Fixed(1)) re-admits its next
        // window instead of degrading to unbudgeted heads.
        drop(slot.guard.take());
        if let Some(e) = slot.err.take() {
            return Err(e);
        }

        let plan = self.plan.clone();
        let window = &plan.windows[idx];
        for (i, pb) in window.baskets.iter().enumerate() {
            let part = slot.parts[i].take().ok_or_else(|| {
                Error::Sync(format!(
                    "prefetch: decoded basket ({},{}) missing from its window",
                    pb.branch, pb.basket
                ))
            })?;
            sink(pb, part)?;
        }
        self.delivered += 1;
        self.consumed_baskets += window.baskets.len() as u64;
        self.consumed_fetches += window.fetches.len() as u64;
        self.consumed_stored += window.stored_bytes();

        // Feed the controller (cumulative totals, diffed internally)
        // and refill the window so the next fetches start before the
        // consumer goes back to work. Only the stall/decode ratio is
        // fed: admission denials are *not* a grow signal — growing the
        // window cannot reduce them (one admission per cluster either
        // way), and under shared-budget contention a denial-per-window
        // stream would pin itself at max and never shrink. Denials
        // stay a diagnostic ([`PrefetchStats::admission_denials`]).
        self.controller.observe(
            self.stall,
            Duration::from_nanos(self.shared.decode_nanos.load(Ordering::Relaxed)),
            0,
        );
        self.pump();
        Ok(true)
    }

    /// Drain the stream, concatenating every cluster into whole
    /// columns — the materialising consumption `coordinator::read`
    /// wires behind [`crate::coordinator::read::ReadOptions`]'s
    /// `prefetch` knob. Each decoded basket is appended exactly once
    /// into the output column (parity with the per-basket read path —
    /// no intermediate per-cluster materialisation), and the stream
    /// fuses on error exactly like [`ClusterStream::next`].
    pub fn read_all_columns(&mut self) -> Result<Vec<ColumnData>> {
        let mut out: Vec<ColumnData> =
            self.slot_types.iter().map(|&ty| ColumnData::new(ty)).collect();
        loop {
            if self.failed {
                return Err(Error::Sync(
                    "prefetch: stream already failed; clusters past the error are \
                     unavailable"
                        .into(),
                ));
            }
            let more = self.consume_next(|pb, part| {
                if out[pb.slot].is_empty() {
                    out[pb.slot] = part;
                    Ok(())
                } else {
                    out[pb.slot].append(&part)
                }
            });
            match more {
                Ok(true) => {}
                Ok(false) => return Ok(out),
                Err(e) => {
                    self.failed = true;
                    return Err(e);
                }
            }
        }
    }

    pub fn stats(&self) -> PrefetchStats {
        let resil = match (self.file.backend().resilience(), &self.resil0) {
            (Some(now), Some(base)) => now.since(base),
            _ => ResilienceStats::default(),
        };
        PrefetchStats {
            clusters: self.delivered,
            baskets: self.consumed_baskets,
            device_reads: self.consumed_fetches,
            stored_bytes: self.consumed_stored,
            bytes_selected: self.plan.bytes_selected,
            bytes_skipped: self.plan.bytes_skipped,
            pages_pruned: self.plan.pages_pruned,
            bytes_pruned: self.plan.bytes_pruned,
            fetch_stall: self.stall,
            fetch_time: Duration::from_nanos(
                self.shared.fetch_nanos.load(Ordering::Relaxed),
            ),
            decode_time: Duration::from_nanos(
                self.shared.decode_nanos.load(Ordering::Relaxed),
            ),
            admission_denials: self.admission_denials,
            retries: resil.retries,
            hedges: resil.hedges,
            hedge_wins: resil.hedge_wins,
            deadline_misses: resil.deadline_misses,
            degraded_windows: self.degraded_windows,
            window: self.controller.summary(),
        }
    }

    /// Completed submit→fully-decoded wall latency distribution over
    /// every non-empty window so far — the tail hedged reads compress
    /// (see the `remote_reads` experiment's p99 column). Log-bucketed
    /// ([`HistSnapshot::p50`]/[`p95`](HistSnapshot::p95)/
    /// [`p99`](HistSnapshot::p99)); windows that errored out record
    /// nothing.
    pub fn window_latency(&self) -> HistSnapshot {
        self.shared.window_hist.snapshot()
    }

    /// The window controller's replayable decision trace.
    pub fn window_trace(&self) -> &[Decision] {
        self.controller.trace()
    }

    /// The stream's current fair share of the session read budget.
    pub fn fair_share(&self) -> usize {
        self.reg.fair_share()
    }

    /// Highest in-flight window count this stream ever held (fairness
    /// tests assert it never exceeds the share).
    pub fn admission_high_water(&self) -> usize {
        self.reg.high_water()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Codec, Settings};
    use crate::format::writer::FileWriter;
    use crate::format::Directory;
    use crate::imt::Pool;
    use crate::serial::schema::Schema;
    use crate::serial::value::Value;
    use crate::storage::mem::MemBackend;
    use crate::storage::BackendRef;
    use crate::tree::sink::FileSink;
    use crate::tree::writer::{FlushMode, TreeWriter, WriterConfig};
    use crate::cache::window::WindowConfig;

    fn build(
        n_branches: usize,
        entries: usize,
        basket_entries: usize,
        codec: Settings,
    ) -> Arc<FileReader> {
        let schema = Schema::flat_f32("c", n_branches);
        let be: BackendRef = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
        let sink = FileSink::new(fw.clone(), n_branches);
        let cfg = WriterConfig {
            basket_entries,
            compression: codec,
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        for i in 0..entries {
            let row: Vec<Value> =
                (0..n_branches).map(|b| Value::F32(((i * (b + 3)) % 89) as f32 * 0.25)).collect();
            w.fill(row).unwrap();
        }
        let (sink, n, _) = w.close().unwrap();
        let meta = sink.into_meta("t".into(), schema, n).unwrap();
        fw.finish(&Directory { trees: vec![meta] }).unwrap();
        Arc::new(FileReader::open(be).unwrap())
    }

    fn serial_columns(reader: &TreeReader) -> Vec<ColumnData> {
        reader.read_all().unwrap()
    }

    #[test]
    fn stream_matches_serial_read_inline() {
        // No pool anywhere: tasks run inline, the stream degrades to a
        // serial — but still coalesced — read.
        let file = build(3, 1000, 128, Settings::new(Codec::Rzip, 3));
        let reader = TreeReader::open_first(file).unwrap();
        let mut stream = ClusterStream::open(&reader, &PrefetchOptions::default()).unwrap();
        let cols = stream.read_all_columns().unwrap();
        assert_eq!(cols, serial_columns(&reader));
        let st = stream.stats();
        assert_eq!(st.clusters, 8, "1000 entries / 128 per cluster");
        assert_eq!(st.baskets, 24);
        assert!(
            st.device_reads <= 8,
            "coalescing must not exceed one read per cluster: {}",
            st.device_reads
        );
        assert!(st.coalescing_factor() >= 3.0, "3 baskets per cluster read");
    }

    #[test]
    fn stream_matches_serial_read_on_a_pool() {
        let file = build(4, 2000, 256, Settings::new(Codec::Lz4r, 3));
        let reader = TreeReader::open_first(file).unwrap();
        let pool = Arc::new(Pool::new(4));
        let session = Session::with_pool(pool, SessionConfig::default());
        for window in [
            WindowPolicy::None,
            WindowPolicy::Fixed(3),
            WindowPolicy::Adaptive(WindowConfig::default()),
        ] {
            let opts = PrefetchOptions { window, ..Default::default() };
            let mut stream =
                ClusterStream::open_in_session(&reader, &opts, &session).unwrap();
            let cols = stream.read_all_columns().unwrap();
            assert_eq!(cols, serial_columns(&reader), "window {window:?}");
        }
        assert_eq!(session.stats().in_flight_read_windows, 0, "all slots returned");
    }

    #[test]
    fn clusters_arrive_in_order_with_entry_ranges() {
        let file = build(2, 700, 100, Settings::uncompressed());
        let reader = TreeReader::open_first(file).unwrap();
        let mut stream =
            ClusterStream::open(&reader, &PrefetchOptions::fixed(4)).unwrap();
        let mut first = 0u64;
        let mut idx = 0usize;
        while let Some(c) = stream.next().unwrap() {
            assert_eq!(c.index, idx);
            assert_eq!(c.first_entry, first);
            assert_eq!(c.columns.len(), 2);
            assert_eq!(c.columns[0].len() as u64, c.entries);
            first += c.entries;
            idx += 1;
        }
        assert_eq!(first, 700);
        assert_eq!(idx, 7);
    }

    #[test]
    fn branch_selection_streams_a_subset_in_selection_order() {
        let file = build(5, 600, 128, Settings::new(Codec::Rzip, 2));
        let reader = TreeReader::open_first(file).unwrap();
        let opts = PrefetchOptions {
            branches: Some(vec![3, 1]),
            ..Default::default()
        };
        let mut stream = ClusterStream::open(&reader, &opts).unwrap();
        let cols = stream.read_all_columns().unwrap();
        let all = serial_columns(&reader);
        assert_eq!(cols.len(), 2);
        assert_eq!(cols[0], all[3]);
        assert_eq!(cols[1], all[1]);
    }

    #[test]
    fn uneven_shapes_stream_identically_to_serial() {
        // (branches, entries, basket) incl. partial tails, single
        // basket, empty tree, one-entry baskets.
        let shapes = [
            (4, 1000, 256),
            (3, 100, 100),
            (5, 7, 1000),
            (1, 513, 64),
            (2, 0, 128),
            (6, 256, 1),
        ];
        let pool = Arc::new(Pool::new(3));
        for (nb, entries, basket) in shapes {
            let file = build(nb, entries, basket, Settings::new(Codec::Rzip, 3));
            let reader = TreeReader::open_first(file).unwrap();
            let session = Session::with_pool(pool.clone(), SessionConfig::default());
            let mut stream = ClusterStream::open_in_session(
                &reader,
                &PrefetchOptions::default(),
                &session,
            )
            .unwrap();
            let cols = stream.read_all_columns().unwrap();
            assert_eq!(
                cols,
                serial_columns(&reader),
                "shape ({nb}, {entries}, {basket})"
            );
        }
    }

    #[test]
    fn two_streams_split_the_read_budget_fairly() {
        let file = build(2, 1200, 100, Settings::uncompressed());
        let reader = TreeReader::open_first(file).unwrap();
        let pool = Arc::new(Pool::new(2));
        let session = Session::with_pool(
            pool,
            SessionConfig { max_inflight_read_windows: 4, ..Default::default() },
        );
        let opts = PrefetchOptions::fixed(8); // wants more than its share
        let mut s1 = ClusterStream::open_in_session(&reader, &opts, &session).unwrap();
        let mut s2 = ClusterStream::open_in_session(&reader, &opts, &session).unwrap();
        assert_eq!(s1.fair_share(), 2, "4 slots over 2 readers");
        let a = s1.read_all_columns().unwrap();
        let b = s2.read_all_columns().unwrap();
        assert_eq!(a, b);
        assert!(
            s1.admission_high_water() <= 2 && s2.admission_high_water() <= 2,
            "streams must stay within their fair share: {} / {}",
            s1.admission_high_water(),
            s2.admission_high_water()
        );
        assert_eq!(session.stats().in_flight_read_windows, 0);
    }

    #[test]
    fn predicate_pruned_stream_skips_pages_and_stays_row_aligned() {
        // Monotonic values: every 100-entry cluster's zone on branch 0
        // is a disjoint [k·100, k·100+99] band, so `x >= 500` prunes
        // exactly the first five clusters — of *both* branches, so the
        // surviving concatenated columns stay equal-length.
        let schema = Schema::flat_f32("c", 2);
        let be: BackendRef = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
        let sink = FileSink::new(fw.clone(), 2);
        let cfg = WriterConfig {
            basket_entries: 100,
            compression: Settings::uncompressed(),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        for i in 0..1000 {
            w.fill(vec![Value::F32(i as f32), Value::F32(-(i as f32))]).unwrap();
        }
        let (sink, n, _) = w.close().unwrap();
        let meta = sink.into_meta("t".into(), schema, n).unwrap();
        fw.finish(&Directory { trees: vec![meta] }).unwrap();
        let reader =
            TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        let full = serial_columns(&reader);
        let opts = PrefetchOptions {
            predicate: Some(super::super::plan::Predicate::ge(0, 500.0)),
            ..Default::default()
        };
        let mut stream = ClusterStream::open(&reader, &opts).unwrap();
        let cols = stream.read_all_columns().unwrap();
        assert_eq!(cols[0].len(), 500, "first five clusters pruned");
        assert_eq!(cols[1].len(), 500, "sibling column pruned identically");
        for i in 0..500 {
            assert_eq!(cols[0].get(i), full[0].get(i + 500));
            assert_eq!(cols[1].get(i), full[1].get(i + 500));
        }
        let st = stream.stats();
        assert_eq!(st.pages_pruned, 10, "5 clusters × 2 branches");
        assert!(st.bytes_pruned > 0);
        assert_eq!(st.clusters, 10, "pruned windows still deliver (empty)");
        assert_eq!(st.baskets, 10, "only surviving baskets decode");
        assert_eq!(st.device_reads, 5, "pruned windows fetch nothing");
        assert_eq!(st.bytes_skipped, 0, "both branches selected");
    }

    #[test]
    fn corrupt_basket_surfaces_as_error_not_hang() {
        let schema = Schema::flat_f32("c", 2);
        let be: BackendRef = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
        let sink = FileSink::new(fw.clone(), 2);
        let cfg = WriterConfig {
            basket_entries: 64,
            compression: Settings::new(Codec::Rzip, 3),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        for i in 0..256 {
            w.fill(vec![Value::F32(i as f32), Value::F32(i as f32 * 2.0)]).unwrap();
        }
        let (sink, n, _) = w.close().unwrap();
        let meta = sink.into_meta("t".into(), schema, n).unwrap();
        // Flip a stored byte of the third cluster's payload region
        // (XOR so the corruption can never be a no-op).
        let victim = meta.branches[0].baskets[2].offset;
        fw.finish(&Directory { trees: vec![meta] }).unwrap();
        let mut byte = [0u8; 1];
        be.read_at(victim, &mut byte).unwrap();
        be.write_at(victim, &[byte[0] ^ 0xFF]).unwrap();
        let reader =
            TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        let pool = Arc::new(Pool::new(2));
        let session = Session::with_pool(pool, SessionConfig::default());
        let mut stream = ClusterStream::open_in_session(
            &reader,
            &PrefetchOptions::fixed(4),
            &session,
        )
        .unwrap();
        let err = stream.read_all_columns();
        assert!(err.is_err(), "corruption must surface as an error");
        // Fused: a failed stream keeps failing rather than silently
        // yielding clusters past the hole.
        assert!(stream.next().is_err(), "failed stream must stay failed");
        assert!(stream.next().is_err());
        drop(stream);
        // In-flight windows finish inside the session's completion
        // domain; only then may the no-leak invariant be asserted.
        session.drain().unwrap();
        assert_eq!(
            session.stats().in_flight_read_windows,
            0,
            "no budget slot may leak past a failed stream"
        );
    }

    #[test]
    fn stats_track_window_adaptation() {
        let file = build(3, 3000, 100, Settings::new(Codec::Lz4r, 2));
        let reader = TreeReader::open_first(file).unwrap();
        let pool = Arc::new(Pool::new(2));
        let session = Session::with_pool(pool, SessionConfig::default());
        let mut stream = ClusterStream::open_in_session(
            &reader,
            &PrefetchOptions::default(),
            &session,
        )
        .unwrap();
        let cols = stream.read_all_columns().unwrap();
        assert_eq!(cols[0].len(), 3000);
        let st = stream.stats();
        assert_eq!(st.clusters, 30);
        assert_eq!(st.baskets, 90);
        assert!(st.window.clusters == 30, "controller observed every cluster");
        assert!(st.window.last_entries >= 1);
        assert!(!stream.window_trace().is_empty(), "adaptive trace recorded");
    }

    #[test]
    fn degraded_backend_streams_head_only_and_byte_identical() {
        use crate::storage::resilient::{ResilientBackend, ResilientConfig};
        // Re-open the same stored bytes behind a ResilientBackend with
        // its breaker forced open: the pump must stop speculating
        // (every window head-only, counted as degraded), the head
        // windows must pass the breaker's gate, and the stream must
        // still decode byte-identically.
        let file = build(3, 1000, 128, Settings::new(Codec::Rzip, 3));
        let plain = TreeReader::open_first(file.clone()).unwrap();
        let expect = serial_columns(&plain);
        let res = Arc::new(ResilientBackend::new(
            file.backend().clone(),
            ResilientConfig::default(),
        ));
        res.force_breaker(true);
        let guarded: BackendRef = res.clone();
        let reader =
            TreeReader::open_first(Arc::new(FileReader::open(guarded).unwrap())).unwrap();
        let pool = Arc::new(Pool::new(3));
        let session = Session::with_pool(pool, SessionConfig::default());
        let mut stream = ClusterStream::open_in_session(
            &reader,
            &PrefetchOptions::fixed(4),
            &session,
        )
        .unwrap();
        let cols = stream.read_all_columns().unwrap();
        assert_eq!(cols, expect, "degraded stream must stay byte-identical");
        let st = stream.stats();
        assert_eq!(st.clusters, 8);
        assert_eq!(
            st.degraded_windows, 8,
            "every window submitted while the breaker was open counts"
        );
        assert_eq!(st.retries, 0, "head reads pass the open breaker first try");
        assert_eq!(stream.window_latency().count(), 8);
        drop(stream);
        session.drain().unwrap();
        assert_eq!(session.stats().in_flight_read_windows, 0);
    }

    #[test]
    fn shed_read_ahead_window_is_refetched_inline_at_head_priority() {
        use crate::storage::{IoHints, ReadPriority};
        /// Sheds every read-ahead request while reporting itself
        /// healthy — isolates the consumer's inline-recovery path from
        /// the pump's health-based degradation.
        struct ShedReadAhead {
            inner: BackendRef,
            shed: AtomicU64,
        }
        impl crate::storage::Backend for ShedReadAhead {
            fn read_at(&self, off: u64, buf: &mut [u8]) -> crate::error::Result<()> {
                self.inner.read_at(off, buf)
            }
            fn write_at(&self, off: u64, data: &[u8]) -> crate::error::Result<()> {
                self.inner.write_at(off, data)
            }
            fn len(&self) -> crate::error::Result<u64> {
                self.inner.len()
            }
            fn describe(&self) -> String {
                "shed-read-ahead".into()
            }
            fn read_at_opts(
                &self,
                off: u64,
                buf: &mut [u8],
                hints: IoHints,
            ) -> crate::error::Result<()> {
                if hints.priority == ReadPriority::ReadAhead {
                    self.shed.fetch_add(1, Ordering::Relaxed);
                    return Err(Error::Shed("test: read-ahead refused".into()));
                }
                self.inner.read_at(off, buf)
            }
        }
        let file = build(3, 1000, 128, Settings::new(Codec::Rzip, 3));
        let shed = Arc::new(ShedReadAhead {
            inner: file.backend().clone(),
            shed: AtomicU64::new(0),
        });
        let guarded: BackendRef = shed.clone();
        let reader =
            TreeReader::open_first(Arc::new(FileReader::open(guarded).unwrap())).unwrap();
        let plain = TreeReader::open_first(file).unwrap();
        // Inline (no pool): every fetch and every recovery is
        // synchronous, so the shed/recovery schedule is deterministic.
        let mut stream =
            ClusterStream::open(&reader, &PrefetchOptions::fixed(4)).unwrap();
        let cols = stream.read_all_columns().unwrap();
        assert_eq!(cols, serial_columns(&plain), "recovery must be lossless");
        let st = stream.stats();
        assert_eq!(st.clusters, 8);
        assert_eq!(
            st.degraded_windows, 7,
            "all but the first window were shed as read-ahead and recovered"
        );
        assert_eq!(shed.shed.load(Ordering::Relaxed), 7, "one shed per window");
    }

    #[test]
    fn dropping_a_stream_midway_releases_everything() {
        let file = build(2, 2000, 100, Settings::new(Codec::Rzip, 2));
        let reader = TreeReader::open_first(file).unwrap();
        let pool = Arc::new(Pool::new(2));
        let session = Session::with_pool(pool, SessionConfig::default());
        {
            let mut stream = ClusterStream::open_in_session(
                &reader,
                &PrefetchOptions::fixed(6),
                &session,
            )
            .unwrap();
            // Consume only a prefix, leaving prefetched windows live.
            for _ in 0..3 {
                stream.next().unwrap().unwrap();
            }
        }
        // Outstanding fetch/decode tasks finish inside the session's
        // completion domain; afterwards no slot may remain held.
        session.drain().unwrap();
        assert_eq!(session.stats().in_flight_read_windows, 0);
        assert_eq!(session.stats().active_readers, 0);
    }
}
