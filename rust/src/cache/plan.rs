//! Cluster fetch plan: which baskets each cluster window needs, and
//! how to **coalesce** their stored ranges into single device reads.
//!
//! ROOT's TTreeCache gains most of its read-path win before any thread
//! touches a byte: the baskets of one cluster sit adjacent in the file
//! (the writer appends them cluster-major), so fetching them as one
//! vectored read replaces `branches × 1` seeking reads with a single
//! sequential one. [`ClusterPlan::build`] precomputes exactly that:
//! per cluster window, the planned baskets of every selected branch
//! and the minimal set of [`FetchRange`]s covering them, merging
//! ranges separated by at most `coalesce_gap` slack bytes (slack is
//! read and discarded — on seek-dominated devices that is far cheaper
//! than a second operation).
//!
//! Cluster boundaries come from the *first selected branch*. Trees cut
//! by [`crate::tree::writer::TreeWriter`] are cluster-aligned, so every
//! branch contributes exactly one basket per window; a misaligned tree
//! degrades gracefully — each basket lands in the window containing
//! its first entry, per-branch order is preserved, and concatenating a
//! stream's windows still rebuilds every column in entry order.

use crate::error::{Error, Result};
use crate::format::directory::{BasketInfo, BranchMeta, TreeMeta, ZoneMap};
use crate::serial::schema::ColumnType;
use crate::storage::BackendRef;

/// Comparison operator of a pushed-down range predicate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredOp {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

/// A `branch op constant` range predicate, pushed below the fetch
/// plan: pages whose [`ZoneMap`] provably excludes every matching row
/// are never fetched (counted in [`ClusterPlan::pages_pruned`] /
/// [`ClusterPlan::bytes_pruned`]). Pruning is *conservative* — a page
/// without a zone (older wire, NaN present) always survives — so the
/// surviving rows are a superset of the matching rows and a residual
/// row filter ([`Predicate::matches`]) makes the result exact.
///
/// Only fixed-width numeric branches can carry a predicate; the
/// constant is compared in `f64` on both the pruning and the residual
/// path, so the two always agree.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Predicate {
    /// Branch the predicate constrains.
    pub branch: usize,
    pub op: PredOp,
    pub value: f64,
}

impl Predicate {
    pub fn lt(branch: usize, value: f64) -> Self {
        Predicate { branch, op: PredOp::Lt, value }
    }
    pub fn le(branch: usize, value: f64) -> Self {
        Predicate { branch, op: PredOp::Le, value }
    }
    pub fn gt(branch: usize, value: f64) -> Self {
        Predicate { branch, op: PredOp::Gt, value }
    }
    pub fn ge(branch: usize, value: f64) -> Self {
        Predicate { branch, op: PredOp::Ge, value }
    }
    pub fn eq(branch: usize, value: f64) -> Self {
        Predicate { branch, op: PredOp::Eq, value }
    }
    pub fn ne(branch: usize, value: f64) -> Self {
        Predicate { branch, op: PredOp::Ne, value }
    }

    /// Row-level evaluation — the residual filter applied after
    /// pruning (NaN rows fail every comparison except `!=`, matching
    /// IEEE semantics).
    pub fn matches(&self, v: f64) -> bool {
        match self.op {
            PredOp::Lt => v < self.value,
            PredOp::Le => v <= self.value,
            PredOp::Gt => v > self.value,
            PredOp::Ge => v >= self.value,
            PredOp::Eq => v == self.value,
            PredOp::Ne => v != self.value,
        }
    }

    /// Can a page whose values span `zone` contain a matching row?
    /// `false` only when the zone provably excludes every row.
    pub fn selects_zone(&self, zone: &ZoneMap) -> bool {
        let (lo, hi) = (zone.min(), zone.max());
        match self.op {
            PredOp::Lt => lo < self.value,
            PredOp::Le => lo <= self.value,
            PredOp::Gt => hi > self.value,
            PredOp::Ge => hi >= self.value,
            PredOp::Eq => self.value >= lo && self.value <= hi,
            PredOp::Ne => !(lo == hi && lo == self.value),
        }
    }

    /// Validate against a tree: the branch must exist and be a
    /// fixed-width numeric column (zones order values as `f64`; byte
    /// strings have no order here and list branches would need
    /// per-element semantics).
    fn check(&self, meta: &TreeMeta) -> Result<()> {
        let Some(br) = meta.branches.get(self.branch) else {
            return Err(Error::Coordinator(format!(
                "predicate: branch index {} out of range ({} branches)",
                self.branch,
                meta.branches.len()
            )));
        };
        match br.ty {
            ColumnType::I32
            | ColumnType::I64
            | ColumnType::F32
            | ColumnType::F64
            | ColumnType::U8 => {}
            other => {
                return Err(Error::Coordinator(format!(
                    "predicate: branch '{}' has non-scalar type {other:?}; range \
                     predicates need a fixed-width numeric branch",
                    br.name
                )));
            }
        }
        if self.value.is_nan() {
            return Err(Error::Coordinator(
                "predicate: comparison against NaN never matches".into(),
            ));
        }
        Ok(())
    }
}

/// Merge half-open `[start, end)` entry ranges into a sorted disjoint
/// union (empty ranges dropped).
fn merge_ranges(mut v: Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    v.retain(|&(s, e)| e > s);
    v.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::new();
    for (s, e) in v {
        match out.last_mut() {
            Some(r) if s <= r.1 => r.1 = r.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

/// Is `[s, e)` fully inside one of the (merged, disjoint) `ranges`?
fn covered(ranges: &[(u64, u64)], s: u64, e: u64) -> bool {
    ranges.iter().any(|&(lo, hi)| lo <= s && e <= hi)
}

/// The subset of `ranges` this branch can realise as whole pages: the
/// merged union of its baskets lying fully inside a range. Pruning a
/// partial page would desynchronise this branch's surviving rows from
/// its siblings', so anything less than a whole page is given back.
fn prunable(br: &BranchMeta, ranges: &[(u64, u64)]) -> Vec<(u64, u64)> {
    merge_ranges(
        br.baskets
            .iter()
            .map(|k| (k.first_entry, k.first_entry + k.n_entries as u64))
            .filter(|&(s, e)| covered(ranges, s, e))
            .collect(),
    )
}

/// One basket (or page pair) scheduled inside a cluster window.
#[derive(Clone, Copy, Debug)]
pub struct PlannedBasket {
    /// Index into the stream's *selection* (its output column slot).
    pub slot: usize,
    /// Branch index in the tree.
    pub branch: usize,
    /// Basket index within the branch.
    pub basket: usize,
    /// Decode target type.
    pub ty: ColumnType,
    /// Stored location + integrity info.
    pub info: BasketInfo,
    /// Paged variable-length branch: the paired element page, stored
    /// directly after `info` (the v3 adjacency invariant), so one
    /// contiguous span of `info.comp_len + elem.comp_len` bytes covers
    /// the pair.
    pub elem: Option<BasketInfo>,
}

impl PlannedBasket {
    /// Stored bytes this planned unit fetches (offset + element page).
    pub fn stored_len(&self) -> u64 {
        self.info.comp_len as u64 + self.elem.map_or(0, |e| e.comp_len as u64)
    }
}

/// One coalesced device fetch: a contiguous stored range covering one
/// or more baskets (plus any sub-gap slack between them).
#[derive(Clone, Debug)]
pub struct FetchRange {
    pub offset: u64,
    pub len: usize,
    /// `(basket index within the window, byte offset within this
    /// range)` for every basket the range covers.
    pub parts: Vec<(usize, usize)>,
}

/// One cluster window: entry range, planned baskets, coalesced reads.
#[derive(Clone, Debug)]
pub struct ClusterWindow {
    pub index: usize,
    /// First entry of the window (lead-branch cluster cut).
    pub first_entry: u64,
    /// Entries the window covers on the lead branch.
    pub entries: u64,
    /// Slot-major, basket-ascending — consuming them in order rebuilds
    /// each selected column's window chunk in entry order.
    pub baskets: Vec<PlannedBasket>,
    pub fetches: Vec<FetchRange>,
}

impl ClusterWindow {
    /// Stored (compressed) bytes the window's baskets occupy
    /// (element pages of paged branches included).
    pub fn stored_bytes(&self) -> u64 {
        self.baskets.iter().map(|b| b.stored_len()).sum()
    }
}

/// A tree's whole fetch plan for one branch selection.
#[derive(Clone, Debug, Default)]
pub struct ClusterPlan {
    pub windows: Vec<ClusterWindow>,
    /// Total planned baskets — the device reads a per-basket fetcher
    /// would issue; [`ClusterPlan::total_fetches`] is what coalescing
    /// issues instead.
    pub total_baskets: usize,
    /// Stored bytes the selection will actually fetch (projection
    /// pushdown's numerator).
    pub bytes_selected: u64,
    /// Stored bytes of the tree's *other* branches that the projection
    /// never touches — what a full-cluster decode would have read on
    /// top of `bytes_selected` (and `bytes_pruned`).
    pub bytes_skipped: u64,
    /// Pages of *selected* branches a pushed-down predicate's zone
    /// maps excluded from the plan (element pages of pruned pairs
    /// count too).
    pub pages_pruned: u64,
    /// Stored bytes those pruned pages would have fetched — pushdown's
    /// saving *below* the projection split:
    /// `bytes_selected + bytes_pruned + bytes_skipped` partition the
    /// tree's stored bytes.
    pub bytes_pruned: u64,
}

impl ClusterPlan {
    /// Build the plan for `selection` over `meta`, merging stored
    /// ranges separated by at most `coalesce_gap` bytes.
    pub fn build(meta: &TreeMeta, selection: &[usize], coalesce_gap: u32) -> Result<ClusterPlan> {
        Self::build_filtered(meta, selection, coalesce_gap, None)
    }

    /// As [`ClusterPlan::build`], additionally pruning pages a range
    /// predicate's zone maps exclude.
    ///
    /// Pruned entry ranges are identical across every selected branch
    /// (whole pages only, shrunk to what all branches can realise), so
    /// the surviving window chunks stay row-aligned: concatenated
    /// columns keep equal lengths and a residual row filter over them
    /// is exact. Files without zones (wire v1–v3) plan unpruned.
    pub fn build_filtered(
        meta: &TreeMeta,
        selection: &[usize],
        coalesce_gap: u32,
        predicate: Option<&Predicate>,
    ) -> Result<ClusterPlan> {
        for (i, &b) in selection.iter().enumerate() {
            if b >= meta.branches.len() {
                return Err(Error::Coordinator(format!(
                    "prefetch: branch index {b} out of range ({} branches)",
                    meta.branches.len()
                )));
            }
            // A duplicated selection would double-fetch and
            // double-count the branch's bytes (breaking the
            // selected+skipped partition) and emit the column twice.
            if selection[..i].contains(&b) {
                return Err(Error::Coordinator(format!(
                    "prefetch: branch index {b} selected more than once"
                )));
            }
        }
        if let Some(p) = predicate {
            p.check(meta)?;
        }
        let Some(&lead) = selection.first() else {
            return Ok(ClusterPlan::default());
        };
        // Entry ranges the predicate's zone maps exclude, shrunk to
        // the whole-page boundaries *every* selected branch shares.
        // The writer seals all branches at identical page cuts, so
        // this normally converges immediately; a foreign misaligned
        // file just prunes less (never inconsistently).
        let excluded: Vec<(u64, u64)> = match predicate {
            None => Vec::new(),
            Some(p) => {
                let pb = &meta.branches[p.branch];
                let mut ex = merge_ranges(
                    pb.baskets
                        .iter()
                        .filter(|k| k.zone.is_some_and(|z| !p.selects_zone(&z)))
                        .map(|k| (k.first_entry, k.first_entry + k.n_entries as u64))
                        .collect(),
                );
                loop {
                    let mut next = ex.clone();
                    for &b in selection {
                        next = prunable(&meta.branches[b], &next);
                    }
                    if next == ex || next.is_empty() {
                        ex = next;
                        break;
                    }
                    ex = next;
                }
                ex
            }
        };
        // Window cuts: the tree's recorded cluster spans (paged v3
        // trees — the lead branch holds many pages per cluster there),
        // else the lead branch's basket boundaries (ascending and
        // gapless per TreeMeta::check).
        let spans: Vec<(u64, u64)> = if meta.clusters.is_empty() {
            meta.branches[lead]
                .baskets
                .iter()
                .map(|k| (k.first_entry, k.n_entries as u64))
                .collect()
        } else {
            meta.clusters.iter().map(|c| (c.first_entry, c.n_entries)).collect()
        };
        if spans.is_empty() {
            return Ok(ClusterPlan::default());
        }
        let cuts: Vec<u64> = spans.iter().map(|&(f, _)| f).collect();
        let mut windows: Vec<ClusterWindow> = spans
            .iter()
            .enumerate()
            .map(|(i, &(first_entry, entries))| ClusterWindow {
                index: i,
                first_entry,
                entries,
                baskets: Vec::new(),
                fetches: Vec::new(),
            })
            .collect();
        let mut total = 0usize;
        let mut bytes_selected = 0u64;
        let mut pages_pruned = 0u64;
        let mut bytes_pruned = 0u64;
        for (slot, &b) in selection.iter().enumerate() {
            let br = &meta.branches[b];
            let paged_list = br.is_paged_list();
            for (k, info) in br.baskets.iter().enumerate() {
                let planned = PlannedBasket {
                    slot,
                    branch: b,
                    basket: k,
                    ty: br.ty,
                    info: *info,
                    elem: paged_list.then(|| br.elems[k]),
                };
                if covered(
                    &excluded,
                    info.first_entry,
                    info.first_entry + info.n_entries as u64,
                ) {
                    // Offset and element pages count separately — the
                    // pair is two stored pages neither of which is
                    // fetched.
                    pages_pruned += 1 + u64::from(planned.elem.is_some());
                    bytes_pruned += planned.stored_len();
                    continue;
                }
                // Window containing this basket's first entry: the
                // last cut at or before it.
                let w = match cuts.binary_search(&info.first_entry) {
                    Ok(i) => i,
                    Err(0) => 0,
                    Err(i) => i - 1,
                };
                bytes_selected += planned.stored_len();
                windows[w].baskets.push(planned);
                total += 1;
            }
        }
        for w in &mut windows {
            let spans: Vec<(u64, usize)> = w
                .baskets
                .iter()
                .map(|b| (b.info.offset, b.stored_len() as usize))
                .collect();
            w.fetches = coalesce(&spans, coalesce_gap);
        }
        let tree_bytes: u64 = meta.branches.iter().map(|br| br.stored_bytes()).sum();
        Ok(ClusterPlan {
            windows,
            total_baskets: total,
            bytes_selected,
            bytes_skipped: tree_bytes.saturating_sub(bytes_selected + bytes_pruned),
            pages_pruned,
            bytes_pruned,
        })
    }

    /// Coalesced device reads across all windows.
    pub fn total_fetches(&self) -> usize {
        self.windows.iter().map(|w| w.fetches.len()).sum()
    }
}

/// Default gap (bytes) bridged when merging adjacent stored ranges —
/// shared by the prefetcher's options and the bulk loader so the
/// layout assumption lives in one place. Also the *floor* of
/// [`adaptive_coalesce_gap`].
pub const DEFAULT_COALESCE_GAP: u32 = 256;

/// Ceiling of [`adaptive_coalesce_gap`]: even on a device whose seek
/// is worth many megabytes of streaming (a WAN object store), slack
/// reads beyond this stop paying for themselves in scratch memory.
pub const MAX_ADAPTIVE_GAP: u32 = 4 * 1024 * 1024;

/// Derive a coalesce gap from observed device cost
/// ([`crate::storage::Backend::cost_hint`]): bridging a gap is worth
/// it while reading the slack bytes costs less than the seek (or
/// first-byte round trip) a split range would pay, i.e. up to
/// `seek_secs × bandwidth` bytes. Clamped to
/// [`DEFAULT_COALESCE_GAP`]..=[`MAX_ADAPTIVE_GAP`]; devices with no
/// hint (plain memory, unknown files) keep the default.
pub fn adaptive_coalesce_gap(hint: Option<crate::storage::CostHint>) -> u32 {
    let Some(h) = hint else { return DEFAULT_COALESCE_GAP };
    if !h.seek_secs.is_finite() || !h.read_mbps.is_finite() {
        return DEFAULT_COALESCE_GAP;
    }
    let bytes = h.seek_secs.max(0.0) * h.read_mbps.max(0.0) * 1e6;
    (bytes as u64).clamp(DEFAULT_COALESCE_GAP as u64, MAX_ADAPTIVE_GAP as u64) as u32
}

/// Merge stored `(offset, len)` spans into the fewest contiguous
/// reads: sort by offset, extend the open range while the next span
/// starts within `gap` bytes of its end (or inside it). The `parts`
/// indices refer to positions in the input slice.
fn coalesce(spans: &[(u64, usize)], gap: u32) -> Vec<FetchRange> {
    coalesce_with_cap(spans, gap, usize::MAX)
}

/// As [`coalesce`], additionally closing a range once admitting the
/// next span would push it past `max_len` bytes. Window plans are
/// naturally bounded (one cluster each); the *bulk* loader is not —
/// a whole file's baskets sit adjacent, so an uncapped merge would
/// produce one file-sized scratch buffer.
fn coalesce_with_cap(
    spans: &[(u64, usize)],
    gap: u32,
    max_len: usize,
) -> Vec<FetchRange> {
    let mut order: Vec<usize> = (0..spans.len()).collect();
    order.sort_by_key(|&i| spans[i].0);
    let mut out: Vec<FetchRange> = Vec::new();
    for &i in &order {
        let (off, len) = spans[i];
        match out.last_mut() {
            Some(r)
                if off <= r.offset + (r.len as u64) + (gap as u64)
                    && (off - r.offset) as usize + len <= max_len =>
            {
                let within = (off - r.offset) as usize;
                r.len = r.len.max(within + len);
                r.parts.push((i, within));
            }
            _ => out.push(FetchRange { offset: off, len, parts: vec![(i, 0)] }),
        }
    }
    out
}

/// Cap on one bulk fetch range ([`fetch_baskets_coalesced`]): an
/// input file's baskets are stored back-to-back, so unbounded merging
/// would coalesce the whole basket region into a single file-sized
/// scratch buffer. 8 MiB still amortises a seek over thousands of
/// baskets while keeping peak scratch flat.
pub const MAX_BULK_FETCH: usize = 8 * 1024 * 1024;

/// Fetch `infos`' stored bytes through coalesced reads — the same
/// range merging the prefetcher plans with, packaged for callers that
/// want owned per-basket bytes (e.g. [`crate::hadd`]'s input loader).
/// Returns one CRC-verified byte vector per input basket, in input
/// order; the coalesced buffers are pooled scratch, each capped at
/// [`MAX_BULK_FETCH`] bytes.
pub fn fetch_baskets_coalesced(
    backend: &BackendRef,
    infos: &[BasketInfo],
    gap: u32,
) -> Result<Vec<Vec<u8>>> {
    let spans: Vec<(u64, usize)> =
        infos.iter().map(|b| (b.offset, b.comp_len as usize)).collect();
    let ranges = coalesce_with_cap(&spans, gap, MAX_BULK_FETCH);
    let mut out: Vec<Vec<u8>> = vec![Vec::new(); infos.len()];
    for r in &ranges {
        let mut buf = crate::compress::pool::get(r.len);
        buf.resize(r.len, 0);
        backend.read_at(r.offset, buf.as_mut_slice())?;
        for &(i, within) in &r.parts {
            let info = &infos[i];
            let bytes = &buf[within..within + info.comp_len as usize];
            crate::format::reader::verify_basket_crc(info, bytes)?;
            out[i] = bytes.to_vec();
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::directory::BranchMeta;
    use crate::serial::schema::{Field, Schema};

    fn info(offset: u64, comp_len: u32, first_entry: u64, n_entries: u32) -> BasketInfo {
        BasketInfo {
            offset,
            comp_len,
            raw_len: comp_len * 4,
            first_entry,
            n_entries,
            crc: 0,
            settings: crate::compress::Settings::default_compressed(),
            zone: None,
        }
    }

    /// `info` with a zone map attached.
    fn zinfo(
        offset: u64,
        comp_len: u32,
        first_entry: u64,
        n_entries: u32,
        lo: f64,
        hi: f64,
    ) -> BasketInfo {
        BasketInfo { zone: ZoneMap::new(lo, hi), ..info(offset, comp_len, first_entry, n_entries) }
    }

    /// 2 branches × 2 clusters, written cluster-major (the tree
    /// writer's layout): each cluster's baskets are adjacent.
    fn aligned_meta() -> TreeMeta {
        let schema = Schema::new(vec![
            Field::new("a", ColumnType::F32),
            Field::new("b", ColumnType::F32),
        ]);
        TreeMeta::classic(
            "t".into(),
            schema,
            200,
            vec![
                BranchMeta::simple(
                    "a".into(),
                    ColumnType::F32,
                    vec![info(24, 100, 0, 100), info(224, 100, 100, 100)],
                ),
                BranchMeta::simple(
                    "b".into(),
                    ColumnType::F32,
                    vec![info(124, 100, 0, 100), info(324, 100, 100, 100)],
                ),
            ],
        )
    }

    /// A v3 paged tree: 2 clusters × (2 f32 pages + 1 list page pair),
    /// column-major per cluster, element pages adjacent to their
    /// offset pages.
    fn paged_meta() -> TreeMeta {
        let schema = Schema::new(vec![
            Field::new("a", ColumnType::F32),
            Field::new("j", ColumnType::ListF32),
        ]);
        TreeMeta {
            name: "t".into(),
            schema,
            entries: 200,
            branches: vec![
                BranchMeta::simple(
                    "a".into(),
                    ColumnType::F32,
                    vec![
                        info(24, 50, 0, 50),
                        info(74, 50, 50, 50),
                        info(224, 50, 100, 50),
                        info(274, 50, 150, 50),
                    ],
                ),
                BranchMeta {
                    name: "j".into(),
                    ty: ColumnType::ListF32,
                    baskets: vec![info(124, 40, 0, 100), info(324, 40, 100, 100)],
                    elems: vec![info(164, 60, 0, 300), info(364, 60, 300, 300)],
                },
            ],
            clusters: vec![
                crate::format::directory::ClusterSpan { first_entry: 0, n_entries: 100 },
                crate::format::directory::ClusterSpan { first_entry: 100, n_entries: 100 },
            ],
        }
    }

    #[test]
    fn paged_tree_windows_follow_cluster_spans_and_pair_element_pages() {
        let meta = paged_meta();
        meta.check().unwrap();
        let plan = ClusterPlan::build(&meta, &[0, 1], 0).unwrap();
        assert_eq!(plan.windows.len(), 2, "windows come from cluster spans, not lead pages");
        assert_eq!(plan.total_baskets, 6);
        let w0 = &plan.windows[0];
        assert_eq!((w0.first_entry, w0.entries), (0, 100));
        assert_eq!(w0.baskets.len(), 3);
        let pair = w0.baskets.iter().find(|b| b.branch == 1).unwrap();
        assert_eq!(pair.elem.unwrap().offset, 164, "list page carries its element page");
        assert_eq!(pair.stored_len(), 100);
        assert!(w0.baskets.iter().filter(|b| b.branch == 0).all(|b| b.elem.is_none()));
        // The cluster's pages are contiguous: one vectored read covers
        // both columns including the offset/element pair.
        assert_eq!(w0.fetches.len(), 1);
        assert_eq!(w0.fetches[0].offset, 24);
        assert_eq!(w0.fetches[0].len, 200);
        assert_eq!(plan.bytes_selected, 400);
        assert_eq!(plan.bytes_skipped, 0);
    }

    #[test]
    fn paged_projection_reports_selected_and_skipped_bytes() {
        let meta = paged_meta();
        let plan = ClusterPlan::build(&meta, &[1], 0).unwrap();
        assert_eq!(plan.total_baskets, 2);
        assert_eq!(plan.bytes_selected, 200, "offset + element pages of the list branch");
        assert_eq!(plan.bytes_skipped, 200, "the unselected f32 pages stay on disk");
        // Each window fetches exactly its pair span, nothing else.
        assert_eq!(plan.windows[0].fetches.len(), 1);
        assert_eq!(plan.windows[0].fetches[0].offset, 124);
        assert_eq!(plan.windows[0].fetches[0].len, 100);
    }

    #[test]
    fn aligned_tree_coalesces_each_cluster_to_one_read() {
        let meta = aligned_meta();
        let plan = ClusterPlan::build(&meta, &[0, 1], 0).unwrap();
        assert_eq!(plan.windows.len(), 2);
        assert_eq!(plan.total_baskets, 4);
        assert_eq!(plan.total_fetches(), 2, "one vectored read per cluster");
        let w0 = &plan.windows[0];
        assert_eq!(w0.first_entry, 0);
        assert_eq!(w0.entries, 100);
        assert_eq!(w0.baskets.len(), 2);
        assert_eq!(w0.fetches.len(), 1);
        assert_eq!(w0.fetches[0].offset, 24);
        assert_eq!(w0.fetches[0].len, 200);
        assert_eq!(w0.fetches[0].parts, vec![(0, 0), (1, 100)]);
        assert_eq!(w0.stored_bytes(), 200);
    }

    #[test]
    fn gap_merges_near_ranges_but_not_far_ones() {
        let mut meta = aligned_meta();
        // Open a 16-byte hole between cluster 0's two baskets.
        meta.branches[1].baskets[0].offset = 140;
        let strict = ClusterPlan::build(&meta, &[0, 1], 0).unwrap();
        assert_eq!(strict.windows[0].fetches.len(), 2, "hole splits with gap 0");
        let loose = ClusterPlan::build(&meta, &[0, 1], 16).unwrap();
        assert_eq!(loose.windows[0].fetches.len(), 1, "gap 16 bridges the hole");
        assert_eq!(loose.windows[0].fetches[0].len, 216);
        assert_eq!(loose.windows[0].fetches[0].parts, vec![(0, 0), (1, 116)]);
    }

    #[test]
    fn subset_selection_plans_only_selected_branches() {
        let meta = aligned_meta();
        let plan = ClusterPlan::build(&meta, &[1], 0).unwrap();
        assert_eq!(plan.total_baskets, 2);
        assert_eq!(plan.windows.len(), 2);
        assert!(plan.windows.iter().all(|w| w.baskets.len() == 1));
        assert_eq!(plan.windows[0].baskets[0].branch, 1);
        assert_eq!(plan.windows[0].baskets[0].slot, 0, "slot is selection-relative");
    }

    #[test]
    fn misaligned_basket_lands_in_covering_window() {
        let mut meta = aligned_meta();
        // Branch 1 cut into 80/120 instead of 100/100: basket 1 starts
        // at entry 80, inside lead window 0.
        meta.branches[1].baskets = vec![info(124, 80, 0, 80), info(324, 120, 80, 120)];
        let plan = ClusterPlan::build(&meta, &[0, 1], 0).unwrap();
        assert_eq!(plan.windows[0].baskets.len(), 3, "both branch-1 baskets in window 0");
        assert_eq!(plan.windows[1].baskets.len(), 1);
        // Per-branch order inside the window stays ascending.
        let b1: Vec<usize> = plan.windows[0]
            .baskets
            .iter()
            .filter(|p| p.branch == 1)
            .map(|p| p.basket)
            .collect();
        assert_eq!(b1, vec![0, 1]);
    }

    #[test]
    fn empty_selection_and_empty_tree_yield_empty_plans() {
        let meta = aligned_meta();
        assert_eq!(ClusterPlan::build(&meta, &[], 0).unwrap().windows.len(), 0);
        let mut empty = aligned_meta();
        empty.entries = 0;
        for br in &mut empty.branches {
            br.baskets.clear();
        }
        assert_eq!(ClusterPlan::build(&empty, &[0, 1], 0).unwrap().windows.len(), 0);
    }

    #[test]
    fn out_of_range_branch_is_an_error() {
        let meta = aligned_meta();
        assert!(ClusterPlan::build(&meta, &[2], 0).is_err());
    }

    /// Duplicate selections would double-fetch a branch and
    /// double-count its bytes, silently breaking the
    /// selected+pruned+skipped partition — they are rejected at plan
    /// build, not deduplicated.
    #[test]
    fn duplicate_branch_selection_is_an_error() {
        let meta = aligned_meta();
        let err = ClusterPlan::build(&meta, &[0, 0], 0).unwrap_err();
        assert!(err.to_string().contains("selected more than once"), "{err}");
        assert!(ClusterPlan::build(&meta, &[1, 0, 1], 0).is_err());
        // Adjacent or not, order independent.
        assert!(ClusterPlan::build(&meta, &[0, 1], 0).is_ok());
    }

    /// `aligned_meta` with zone maps on branch "a": cluster 0 spans
    /// values [0, 9], cluster 1 spans [10, 19].
    fn zoned_meta() -> TreeMeta {
        let mut meta = aligned_meta();
        meta.branches[0].baskets = vec![
            zinfo(24, 100, 0, 100, 0.0, 9.0),
            zinfo(224, 100, 100, 100, 10.0, 19.0),
        ];
        meta
    }

    #[test]
    fn zone_pruning_drops_whole_clusters_and_partitions_bytes() {
        let meta = zoned_meta();
        let pred = Predicate::gt(0, 15.0);
        let plan = ClusterPlan::build_filtered(&meta, &[0, 1], 0, Some(&pred)).unwrap();
        // Cluster 0's zone [0, 9] cannot satisfy `a > 15`: both
        // branches' first baskets are pruned, window 0 plans nothing.
        assert_eq!(plan.pages_pruned, 2);
        assert_eq!(plan.bytes_pruned, 200);
        assert_eq!(plan.bytes_selected, 200);
        assert_eq!(plan.bytes_skipped, 0);
        assert!(plan.windows[0].baskets.is_empty());
        assert!(plan.windows[0].fetches.is_empty());
        assert_eq!(plan.windows[1].baskets.len(), 2);
        let tree_bytes: u64 = meta.branches.iter().map(|br| br.stored_bytes()).sum();
        assert_eq!(plan.bytes_selected + plan.bytes_pruned + plan.bytes_skipped, tree_bytes);
    }

    #[test]
    fn pruning_composes_with_projection_in_the_byte_partition() {
        let meta = zoned_meta();
        let pred = Predicate::lt(0, 5.0);
        // Only branch 0 selected: cluster 1's zone [10, 19] fails
        // `a < 5`, branch 1 is skipped entirely.
        let plan = ClusterPlan::build_filtered(&meta, &[0], 0, Some(&pred)).unwrap();
        assert_eq!(plan.pages_pruned, 1);
        assert_eq!(plan.bytes_pruned, 100);
        assert_eq!(plan.bytes_selected, 100);
        assert_eq!(plan.bytes_skipped, 200, "unselected branch stays 'skipped', not 'pruned'");
    }

    /// A predicate over zone-less pages (older wire, or NaN-bearing
    /// columns) must not prune anything: the plan is byte-identical to
    /// the unfiltered one.
    #[test]
    fn zone_less_pages_are_never_pruned() {
        let meta = aligned_meta();
        let pred = Predicate::eq(0, 123.0);
        let plan = ClusterPlan::build_filtered(&meta, &[0, 1], 0, Some(&pred)).unwrap();
        let plain = ClusterPlan::build(&meta, &[0, 1], 0).unwrap();
        assert_eq!(plan.pages_pruned, 0);
        assert_eq!(plan.bytes_pruned, 0);
        assert_eq!(plan.bytes_selected, plain.bytes_selected);
        assert_eq!(plan.total_baskets, plain.total_baskets);
    }

    /// Misaligned sibling pages shrink the excluded range to what every
    /// branch can realise as whole pages — here branch 1's 80/120 cut
    /// cannot realise any part of the excluded [0, 100), so *nothing*
    /// prunes. Pruning different row sets per branch would tear rows
    /// apart; pruning less is merely slower.
    #[test]
    fn misaligned_branches_prune_consistently_or_not_at_all() {
        let mut meta = zoned_meta();
        meta.branches[1].baskets = vec![info(124, 80, 0, 80), info(324, 120, 80, 120)];
        let pred = Predicate::gt(0, 15.0);
        let plan = ClusterPlan::build_filtered(&meta, &[0, 1], 0, Some(&pred)).unwrap();
        assert_eq!(plan.pages_pruned, 0, "partial-page prune would desynchronise columns");
        assert_eq!(plan.total_baskets, 4);
        // Without the misaligned sibling in the selection, the
        // excluded range is realisable again.
        let solo = ClusterPlan::build_filtered(&meta, &[0], 0, Some(&pred)).unwrap();
        assert_eq!(solo.pages_pruned, 1);
    }

    /// Paged v3 trees prune at page granularity (finer than clusters),
    /// and a pruned offset/element pair counts both stored pages.
    #[test]
    fn paged_tree_prunes_pages_and_counts_element_pairs() {
        let mut meta = paged_meta();
        meta.branches[0].baskets = vec![
            zinfo(24, 50, 0, 50, 0.0, 4.0),
            zinfo(74, 50, 50, 50, 5.0, 9.0),
            zinfo(224, 50, 100, 50, 10.0, 14.0),
            zinfo(274, 50, 150, 50, 15.0, 19.0),
        ];
        let pred = Predicate::ge(0, 10.0);
        let plan = ClusterPlan::build_filtered(&meta, &[0, 1], 0, Some(&pred)).unwrap();
        // Cluster 0's two f32 pages fail the zone test; the list
        // branch's page covers the same [0, 100) span, so its
        // offset+element pair prunes with them: 2 + 2 pages.
        assert_eq!(plan.pages_pruned, 4);
        assert_eq!(plan.bytes_pruned, 200, "100 f32 bytes + 40 offset + 60 element");
        assert_eq!(plan.bytes_selected, 200);
        assert_eq!(plan.bytes_skipped, 0);
        // Page-granular: a predicate excluding only page 0 keeps page 1
        // even though they share a cluster — but then the list page
        // covering [0, 100) cannot prune either, and the fixpoint
        // gives page 0 back too.
        let narrow = Predicate::ge(0, 5.0);
        let p2 = ClusterPlan::build_filtered(&meta, &[0, 1], 0, Some(&narrow)).unwrap();
        assert_eq!(p2.pages_pruned, 0, "list sibling's coarser pages veto a half-cluster prune");
        let p3 = ClusterPlan::build_filtered(&meta, &[0], 0, Some(&narrow)).unwrap();
        assert_eq!(p3.pages_pruned, 1, "f32-only selection prunes the single failing page");
    }

    #[test]
    fn zone_selection_respects_operator_semantics() {
        let z = ZoneMap::new(10.0, 20.0).unwrap();
        assert!(!Predicate::lt(0, 10.0).selects_zone(&z));
        assert!(Predicate::le(0, 10.0).selects_zone(&z));
        assert!(!Predicate::gt(0, 20.0).selects_zone(&z));
        assert!(Predicate::ge(0, 20.0).selects_zone(&z));
        assert!(Predicate::eq(0, 15.0).selects_zone(&z));
        assert!(!Predicate::eq(0, 9.0).selects_zone(&z));
        assert!(Predicate::ne(0, 15.0).selects_zone(&z));
        // A constant-valued page is the only zone `!=` can exclude.
        let c = ZoneMap::new(7.0, 7.0).unwrap();
        assert!(!Predicate::ne(0, 7.0).selects_zone(&c));
        assert!(Predicate::ne(0, 8.0).selects_zone(&c));
    }

    #[test]
    fn predicate_validation_rejects_bad_targets() {
        let meta = paged_meta();
        // Out-of-range branch.
        let plan = ClusterPlan::build_filtered(&meta, &[0], 0, Some(&Predicate::lt(9, 1.0)));
        assert!(plan.is_err());
        // List branch: no scalar order to compare against.
        let list = ClusterPlan::build_filtered(&meta, &[0], 0, Some(&Predicate::lt(1, 1.0)));
        assert!(list.unwrap_err().to_string().contains("non-scalar"));
        // NaN constant: would silently select nothing.
        let nan = ClusterPlan::build_filtered(&meta, &[0], 0, Some(&Predicate::lt(0, f64::NAN)));
        assert!(nan.is_err());
        // The predicate branch need not be selected.
        let ok = ClusterPlan::build_filtered(&meta, &[1], 0, Some(&Predicate::lt(0, 1.0)));
        assert!(ok.is_ok());
    }

    /// The bulk-loader cap closes a range before it outgrows
    /// `max_len`, even over perfectly contiguous baskets.
    #[test]
    fn capped_coalescing_splits_contiguous_runs() {
        let spans: Vec<(u64, usize)> =
            (0..6).map(|i| (24 + i as u64 * 100, 100usize)).collect();
        let uncapped = coalesce_with_cap(&spans, 0, usize::MAX);
        assert_eq!(uncapped.len(), 1, "contiguous run merges fully without a cap");
        let capped = coalesce_with_cap(&spans, 0, 250);
        assert_eq!(capped.len(), 3, "cap 250 admits two 100-byte baskets per range");
        assert!(capped.iter().all(|r| r.len <= 250));
        let covered: usize = capped.iter().map(|r| r.parts.len()).sum();
        assert_eq!(covered, 6, "every basket still covered exactly once");
        // A basket bigger than the cap still gets its own range.
        assert_eq!(coalesce_with_cap(&[(24, 1000)], 0, 250).len(), 1);
    }

    #[test]
    fn adaptive_gap_tracks_device_cost_within_bounds() {
        use crate::storage::CostHint;
        // No hint: the fixed default.
        assert_eq!(adaptive_coalesce_gap(None), DEFAULT_COALESCE_GAP);
        // NVMe-ish: 20 µs seek at 2500 MB/s = 50 KB worth of slack.
        let nvme = adaptive_coalesce_gap(Some(CostHint { seek_secs: 20e-6, read_mbps: 2500.0 }));
        assert_eq!(nvme, 50_000);
        // Tmpfs-ish: seek worth less than the floor.
        let tmpfs = adaptive_coalesce_gap(Some(CostHint { seek_secs: 1e-6, read_mbps: 100.0 }));
        assert_eq!(tmpfs, DEFAULT_COALESCE_GAP);
        // HDD: 8 ms at 160 MB/s = 1.28 MB.
        let hdd = adaptive_coalesce_gap(Some(CostHint { seek_secs: 8e-3, read_mbps: 160.0 }));
        assert_eq!(hdd, 1_280_000);
        // Remote WAN tail: capped at the ceiling.
        let wan = adaptive_coalesce_gap(Some(CostHint { seek_secs: 0.5, read_mbps: 1000.0 }));
        assert_eq!(wan, MAX_ADAPTIVE_GAP);
        // Degenerate hints stay sane.
        assert_eq!(
            adaptive_coalesce_gap(Some(CostHint { seek_secs: f64::NAN, read_mbps: 100.0 })),
            DEFAULT_COALESCE_GAP
        );
    }

    #[test]
    fn coalesced_fetch_returns_verified_per_basket_bytes() {
        use crate::compress::crc32;
        use crate::storage::mem::MemBackend;
        use crate::storage::Backend;
        use std::sync::Arc;
        let be = MemBackend::new();
        let (a, b) = (vec![1u8; 50], vec![2u8; 70]);
        be.write_at(100, &a).unwrap();
        be.write_at(150, &b).unwrap();
        let infos = [
            BasketInfo {
                offset: 100,
                comp_len: 50,
                raw_len: 50,
                first_entry: 0,
                n_entries: 1,
                crc: crc32(&a),
                settings: crate::compress::Settings::uncompressed(),
                zone: None,
            },
            BasketInfo {
                offset: 150,
                comp_len: 70,
                raw_len: 70,
                first_entry: 1,
                n_entries: 1,
                crc: crc32(&b),
                settings: crate::compress::Settings::uncompressed(),
                zone: None,
            },
        ];
        let backend: BackendRef = Arc::new(be);
        let got = fetch_baskets_coalesced(&backend, &infos, 0).unwrap();
        assert_eq!(got, vec![a, b]);
        // Corrupt CRC expectation: the fetch must fail.
        let mut bad = infos;
        bad[1].crc ^= 0xFFFF_FFFF;
        assert!(fetch_baskets_coalesced(&backend, &bad, 0).is_err());
    }
}
