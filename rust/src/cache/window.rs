//! Adaptive prefetch-window sizing — the read-side twin of the write
//! path's cluster sizer.
//!
//! The window (how many clusters the prefetcher keeps in flight ahead
//! of the consumer) faces the same tension the write-side cluster size
//! does: too small and the consumer stalls on storage latency (the
//! paper's serialised-fetch regime), too large and decoded clusters
//! pile up in memory for no gain. One signal decides which side a
//! reader is on: the **fetch-stall / decode ratio** — consumer wall
//! time spent waiting for a cluster that was not ready versus decode
//! CPU burned so far. A stalling consumer means storage latency is
//! exposed, so read further ahead; a stall-free one has slack, so
//! shrink and keep memory flat. (Budget admission *denials* are
//! deliberately not fed as pressure: growing the window cannot reduce
//! them, and under shared-budget contention a denial-per-window
//! stream would pin itself at max — they are reported through
//! [`crate::cache::PrefetchStats`] instead. The controller's `waits`
//! input remains available for callers with a genuine blocking
//! signal.)
//!
//! Rather than re-deriving a controller, [`WindowController`] wraps
//! the write path's [`ClusterSizer`] *as-is* — grow/shrink steps of
//! ×2/÷2, hysteresis, warmup, min/max clamps and the replayable
//! decision trace are identical; only the unit changes ("entries per
//! cluster" becomes "clusters in the window"). Slow storage grows the
//! window toward `max_clusters`; fast storage shrinks it to
//! `min_clusters`, keeping resident memory flat.

use std::time::Duration;

use crate::tree::sizer::{AdaptiveConfig, ClusterSizer, ClusterSizing, Decision, SizerSummary};

/// Read-ahead policy for a [`crate::cache::ClusterStream`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WindowPolicy {
    /// No read-ahead: each cluster is fetched when the consumer asks
    /// for it (window pinned at 1 — fetches still coalesce).
    None,
    /// Keep `k` clusters in flight ahead of the consumer.
    Fixed(usize),
    /// Feedback-sized window per [`WindowConfig`].
    Adaptive(WindowConfig),
}

impl Default for WindowPolicy {
    fn default() -> Self {
        WindowPolicy::Adaptive(WindowConfig::default())
    }
}

impl WindowPolicy {
    /// The most clusters the policy can ever hold in flight — the cap
    /// a stream registers with the session read budget.
    pub fn max_window(&self) -> usize {
        match *self {
            WindowPolicy::None => 1,
            WindowPolicy::Fixed(k) => k.max(1),
            WindowPolicy::Adaptive(cfg) => cfg.max_clusters.max(cfg.min_clusters.max(1)),
        }
    }
}

/// Tuning for [`WindowPolicy::Adaptive`] — the same knobs as the write
/// side's [`AdaptiveConfig`], in window-cluster units.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowConfig {
    /// Hard floor on clusters in flight (>= 1).
    pub min_clusters: usize,
    /// Hard ceiling on clusters in flight.
    pub max_clusters: usize,
    /// Fetch-stall/decode ratio above which a window votes Grow.
    pub grow_stall_ratio: f64,
    /// Ratio below which a wait-free window votes Shrink.
    pub shrink_stall_ratio: f64,
    /// Consecutive same-direction windows required before a step.
    pub hysteresis: u32,
    /// Initial consumed clusters observed without stepping.
    pub warmup: u32,
}

impl Default for WindowConfig {
    fn default() -> Self {
        // Storage-latency signals are strong and consistent (a slow
        // device stalls *every* window), so the read side steps faster
        // than the write sizer: hysteresis 1, a single warmup window.
        WindowConfig {
            min_clusters: 1,
            max_clusters: 8,
            grow_stall_ratio: 0.25,
            shrink_stall_ratio: 0.02,
            hysteresis: 1,
            warmup: 1,
        }
    }
}

/// The per-reader controller, wrapping [`ClusterSizer`] verbatim.
#[derive(Clone, Debug)]
pub struct WindowController {
    sizer: ClusterSizer,
    policy: WindowPolicy,
}

impl WindowController {
    pub fn new(policy: WindowPolicy) -> Self {
        let sizer = match policy {
            WindowPolicy::None => ClusterSizer::new(1, ClusterSizing::Fixed),
            WindowPolicy::Fixed(k) => ClusterSizer::new(k.max(1), ClusterSizing::Fixed),
            WindowPolicy::Adaptive(cfg) => {
                let min = cfg.min_clusters.max(1);
                let max = cfg.max_clusters.max(min);
                ClusterSizer::new(
                    min,
                    ClusterSizing::Adaptive(AdaptiveConfig {
                        min_entries: min,
                        max_entries: max,
                        grow_stall_ratio: cfg.grow_stall_ratio,
                        shrink_stall_ratio: cfg.shrink_stall_ratio,
                        hysteresis: cfg.hysteresis,
                        warmup: cfg.warmup,
                    }),
                )
            }
        };
        WindowController { sizer, policy }
    }

    /// Clusters to hold in flight, counting the one the consumer needs
    /// next.
    pub fn target(&self) -> usize {
        self.sizer.target()
    }

    pub fn is_adaptive(&self) -> bool {
        matches!(self.policy, WindowPolicy::Adaptive(_))
    }

    /// The policy's in-flight cap (see [`WindowPolicy::max_window`]).
    pub fn max_window(&self) -> usize {
        self.policy.max_window()
    }

    /// Feed one consumed cluster: *cumulative* consumer fetch-stall,
    /// *cumulative* decode CPU, and a *cumulative* blocking-wait count
    /// — the exact observe contract of [`ClusterSizer`]. The built-in
    /// prefetcher always passes `waits = 0` (it never blocks, and
    /// admission denials are deliberately not a grow signal — see the
    /// module docs); the input exists for callers with a genuine
    /// blocking backpressure signal.
    pub fn observe(&mut self, fetch_stall: Duration, decode: Duration, waits: u64) {
        self.sizer.observe(fetch_stall, decode, waits);
    }

    /// Replayable decision trace (empty for `None`/`Fixed`).
    pub fn trace(&self) -> &[Decision] {
        self.sizer.trace()
    }

    /// Window band + step counts, reported through
    /// [`crate::cache::PrefetchStats`].
    pub fn summary(&self) -> SizerSummary {
        self.sizer.summary()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn none_and_fixed_never_move() {
        let mut none = WindowController::new(WindowPolicy::None);
        let mut fixed = WindowController::new(WindowPolicy::Fixed(4));
        for i in 1..10u64 {
            none.observe(ms(50 * i), ms(i), i);
            fixed.observe(ms(50 * i), ms(i), i);
        }
        assert_eq!(none.target(), 1);
        assert_eq!(none.max_window(), 1);
        assert_eq!(fixed.target(), 4);
        assert!(none.trace().is_empty() && fixed.trace().is_empty());
    }

    #[test]
    fn sustained_fetch_stall_grows_the_window_to_max() {
        let cfg = WindowConfig { max_clusters: 8, ..Default::default() };
        let mut c = WindowController::new(WindowPolicy::Adaptive(cfg));
        assert_eq!(c.target(), 1, "adaptive starts at the floor");
        // Slow storage: every consumed cluster stalls far past decode.
        for i in 1..12u64 {
            c.observe(ms(20 * i), ms(i), 0);
        }
        assert_eq!(c.target(), 8, "stall-dominated reader reads fully ahead");
        assert_eq!(c.summary().max_entries, 8);
        assert!(c.summary().grows >= 3, "1 -> 2 -> 4 -> 8");
    }

    #[test]
    fn stall_free_reader_shrinks_back_to_min() {
        let cfg = WindowConfig {
            min_clusters: 1,
            max_clusters: 8,
            hysteresis: 1,
            warmup: 0,
            ..Default::default()
        };
        let mut c = WindowController::new(WindowPolicy::Adaptive(cfg));
        // Grow first...
        for i in 1..6u64 {
            c.observe(ms(20 * i), ms(i), 0);
        }
        assert!(c.target() > 1);
        // ...then fast storage: decode keeps accruing, stall stops.
        let stall = ms(100);
        for i in 6..16u64 {
            c.observe(stall, ms(10 * i), 0);
        }
        assert_eq!(c.target(), 1, "memory goes flat when storage is fast");
        assert!(c.summary().shrinks >= 1);
    }

    /// The `waits` input stays live for callers with a real blocking
    /// signal (the prefetcher itself always passes 0 — denials must
    /// not pin the window, see module docs).
    #[test]
    fn blocking_waits_input_still_reads_as_pressure() {
        let cfg = WindowConfig { hysteresis: 1, warmup: 0, ..Default::default() };
        let mut c = WindowController::new(WindowPolicy::Adaptive(cfg));
        c.observe(Duration::ZERO, ms(5), 1); // a genuine blocked admission
        assert_eq!(c.target(), 2, "a waiting window steps like a stalled one");
        assert!(c.trace()[0].waited);
    }

    #[test]
    fn max_window_reflects_the_policy_cap() {
        assert_eq!(WindowPolicy::None.max_window(), 1);
        assert_eq!(WindowPolicy::Fixed(0).max_window(), 1);
        assert_eq!(WindowPolicy::Fixed(5).max_window(), 5);
        let cfg = WindowConfig { min_clusters: 2, max_clusters: 16, ..Default::default() };
        assert_eq!(WindowPolicy::Adaptive(cfg).max_window(), 16);
    }
}
