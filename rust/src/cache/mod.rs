//! Parallel read-ahead cache — the TTreeCache + parallel-unzip
//! analogue ("Optimizing ROOT IO For Analysis" identifies this pair as
//! the decisive read-path optimisation).
//!
//! The basket-granularity read pipeline ([`crate::coordinator::read`])
//! parallelises *within* one call, but nothing hides storage latency
//! between clusters: on seek-dominated devices every per-basket fetch
//! serialises behind the device queue and the pool starves. This
//! subsystem adds the missing layer, in three pieces:
//!
//! * [`plan`] — the **cluster fetch plan**: per cluster window, the
//!   baskets of every selected branch and their stored ranges
//!   **coalesced into single `read_at` fetches** (one vectored read
//!   per window; the writer lays baskets out cluster-major, so a whole
//!   cluster is one contiguous range). [`plan::fetch_baskets_coalesced`]
//!   packages the same merging for bulk loaders ([`crate::hadd`]).
//!   A [`Predicate`] (`branch op constant`) pushes range filtering
//!   below the plan: pages whose wire-v4 zone maps
//!   ([`crate::format::ZoneMap`]) provably exclude every matching row
//!   are never fetched, whole row-aligned pages at a time, with
//!   `pages_pruned`/`bytes_pruned` accounted beside the projection's
//!   selected/skipped split.
//! * [`window`] — the **adaptive window controller**: the write-side
//!   cluster sizer ([`crate::tree::sizer`]) reused as-is (grow/shrink
//!   ×2/÷2, hysteresis, clamps, replayable trace), fed with consumer
//!   fetch-stall vs decode throughput. Slow storage grows the
//!   read-ahead window; fast storage keeps memory flat.
//! * [`prefetch`] — the **[`ClusterStream`]**: walks the cluster list
//!   ahead of the consumer, one session read-budget slot per in-flight
//!   cluster ([`crate::session::Session::register_reader`] — fair-share
//!   admission across N concurrent readers), per-basket decode tasks
//!   on the IMT pool so decode overlaps the next window's fetch, and a
//!   bounded decoded-cluster cache with in-order eviction. Consumption
//!   is strictly in order: [`ClusterStream::next`] yields
//!   [`DecodedCluster`]s whose concatenation is entry-identical to a
//!   serial read.
//!
//! On unreliable storage the stream degrades instead of failing:
//! windows are fetched with head/read-ahead priority hints, a
//! [`crate::storage::BackendHealth::Degraded`] backend shrinks the
//! window to head-only, shed read-ahead is refetched inline, and a
//! backend [`crate::storage::CostHint`] adaptively raises the
//! coalesce gap ([`plan::adaptive_coalesce_gap`]).
//!
//! Entry points: [`crate::tree::reader::TreeReader::stream`],
//! `ReadOptions::prefetch` on [`crate::coordinator::read::read_columns`],
//! and the bounded-memory scan
//! [`crate::framework::dataset::scan_file`].

pub mod plan;
pub mod prefetch;
pub mod window;

pub use plan::{
    adaptive_coalesce_gap, fetch_baskets_coalesced, ClusterPlan, ClusterWindow,
    FetchRange, PlannedBasket, PredOp, Predicate, DEFAULT_COALESCE_GAP,
    MAX_ADAPTIVE_GAP, MAX_BULK_FETCH,
};
pub use prefetch::{ClusterStream, DecodedCluster, PrefetchOptions, PrefetchStats};
pub use window::{WindowConfig, WindowController, WindowPolicy};
