//! Seeded deterministic stress suite (`cargo test --test stress`).
//!
//! Adaptive cluster sizing makes cluster boundaries schedule-dependent,
//! so the invariants here are *semantic*, not byte-level: whatever
//! sizes the controller picks under whatever interleaving, the decoded
//! data must be entry-identical to a fixed-size serial write, budget
//! slots must never leak (even across panics mid-resize), and the
//! narrow-fast-producer workload must converge to a steady size with a
//! better stall/compress ratio than the static starting size.
//!
//! Every randomised test runs once per seed of the pinned matrix
//! (`STRESS_SEEDS`, see `tests/common/stress.rs`); failures print the
//! reproducing seed.

mod common;

use std::sync::Arc;
use std::time::Duration;

use common::stress::stress;
use rootio_par::cache::PrefetchOptions;
use rootio_par::compress::{Codec, Settings};
use rootio_par::coordinator::read::{read_columns, ReadOptions};
use rootio_par::error::Result;
use rootio_par::format::reader::FileReader;
use rootio_par::format::writer::FileWriter;
use rootio_par::format::Directory;
use rootio_par::imt::Pool;
use rootio_par::serial::column::ColumnData;
use rootio_par::serial::schema::Schema;
use rootio_par::serial::value::Row;
use rootio_par::session::{Session, SessionConfig};
use rootio_par::simsched::{simulate, Graph, Place};
use rootio_par::storage::fault::{FaultDirection, FaultKind, FaultPlan, FaultyBackend};
use rootio_par::storage::mem::MemBackend;
use rootio_par::storage::resilient::{ResilientBackend, ResilientConfig, RetryPolicy};
use rootio_par::storage::BackendRef;
use rootio_par::tree::reader::TreeReader;
use rootio_par::tree::sink::{BasketMeta, BasketSink, FileSink, PayloadBuf};
use rootio_par::tree::sizer::{AdaptiveConfig, ClusterSizing, Decision};
use rootio_par::tree::writer::{
    FlushGranularity, FlushMode, TreeWriter, WriteStats, WriterConfig,
};
use rootio_par::metrics::SpanKind;

/// Write `rows` to a file and decode it back: (entries, per-column
/// encoded bytes). The decoded form is what adaptive sizing must keep
/// invariant — cluster boundaries may differ, values may not.
fn write_and_decode(
    schema: &Schema,
    rows: &[Row],
    cfg: WriterConfig,
    session: Option<&Session>,
    version: u32,
) -> (u64, Vec<Vec<u8>>) {
    let be: BackendRef = Arc::new(MemBackend::new());
    let fw = Arc::new(FileWriter::create_versioned(be.clone(), version).unwrap());
    let sink = FileSink::new(fw.clone(), schema.len());
    let mut w = match session {
        Some(s) => TreeWriter::attached(schema.clone(), sink, cfg, s),
        None => TreeWriter::new(schema.clone(), sink, cfg),
    };
    for row in rows {
        w.fill(row.clone()).unwrap();
    }
    let (sink, entries, _) = w.close().unwrap();
    let meta = sink.into_meta("t".into(), schema.clone(), entries).unwrap();
    meta.check().unwrap();
    fw.finish(&Directory { trees: vec![meta] }).unwrap();
    let reader = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
    assert_eq!(reader.entries(), entries);
    let cols = reader.read_all().unwrap();
    (entries, cols.iter().map(|c| c.encode()).collect())
}

/// Satellite: adaptive-sized writes decode to entry-identical data vs
/// `ClusterSizing::Fixed` — across the codec mix, random worker
/// counts, uneven tails, both cluster layouts (classic and paged v3,
/// per the seed's `plan.layout`), and always including the empty-tree
/// and single-entry edge cases. A wire-v1 classic write of the same
/// rows is the third leg: the oldest readable format must decode
/// identically to both v3 layouts.
#[test]
fn prop_adaptive_writes_decode_identical_to_fixed() {
    stress("prop_adaptive_writes_decode_identical_to_fixed", |g, plan| {
        let pool = Arc::new(Pool::new(plan.workers));
        for n_rows in [0usize, 1, plan.n_rows] {
            let rows: Vec<Row> = (0..n_rows).map(|_| g.row(&plan.schema)).collect();
            let fixed_cfg = WriterConfig {
                basket_entries: plan.basket_entries,
                compression: plan.compression,
                flush: FlushMode::Serial,
                ..Default::default()
            };
            let (fixed_entries, fixed) = write_and_decode(
                &plan.schema,
                &rows,
                fixed_cfg.clone(),
                None,
                rootio_par::format::VERSION,
            );
            // v1 wire (classic layout by construction — the paged
            // directory doesn't encode below v3).
            let (v1_entries, v1) = write_and_decode(&plan.schema, &rows, fixed_cfg, None, 1);
            assert_eq!(v1_entries, fixed_entries);
            assert_eq!(v1, fixed, "wire-v1 decode diverged from v3 classic");

            let session = Session::with_pool(
                pool.clone(),
                SessionConfig { max_inflight_clusters: plan.max_inflight, ..Default::default() },
            );
            let adaptive_cfg = WriterConfig {
                basket_entries: plan.basket_entries,
                compression: plan.compression,
                flush: FlushMode::Pipelined,
                granularity: FlushGranularity::Block,
                max_inflight_clusters: plan.max_inflight,
                sizing: plan.sizing,
                selection: plan.selection.clone(),
                layout: plan.layout,
            };
            let (adaptive_entries, adaptive) = write_and_decode(
                &plan.schema,
                &rows,
                adaptive_cfg,
                Some(&session),
                rootio_par::format::VERSION,
            );

            assert_eq!(fixed_entries, n_rows as u64);
            assert_eq!(adaptive_entries, fixed_entries, "entry count diverged");
            assert_eq!(
                adaptive, fixed,
                "adaptive decode diverged from fixed (rows={n_rows}, workers={}, \
                 basket={}, sizing={:?}, layout={:?})",
                plan.workers, plan.basket_entries, plan.sizing, plan.layout,
            );
            assert_eq!(session.stats().in_flight_clusters, 0, "budget fully released");
        }
    });
}

/// Narrow-fast-producer workload used by the convergence test:
/// pre-generated event blocks (production is a memcpy, the PJRT
/// block-landing shape) against heavy rzip compression, so the run is
/// compression-bound by construction and the starting cluster size is
/// deliberately tiny — the regime where per-basket codec setup
/// dominates and the sizer has real room to move.
fn narrow_fast_run(
    pool: &Arc<Pool>,
    sizing: ClusterSizing,
) -> (WriteStats, u64, Vec<Decision>) {
    let n_branches = 2usize;
    let block = 1024usize;
    let blocks = 32usize; // 32_768 entries
    let schema = Schema::flat_f32("v", n_branches);
    let cfg = WriterConfig {
        basket_entries: 16,
        compression: Settings::new(Codec::Rzip, 4),
        flush: FlushMode::Pipelined,
        granularity: FlushGranularity::Block,
        max_inflight_clusters: 4,
        sizing,
        ..Default::default()
    };
    // Produce the blocks up front: the producer's per-cluster cost is
    // the column append alone (fast), so compression stays the
    // bottleneck at every cluster size the sizer can pick.
    let all_blocks: Vec<Vec<ColumnData>> = (0..blocks)
        .map(|blk| {
            let mut rng = rootio_par::framework::dataset::SplitMix::new(blk as u64 + 3);
            (0..n_branches)
                .map(|b| {
                    ColumnData::F32(
                        (0..block)
                            .map(|i| rng.uniform() * (b + 1) as f32 + (i % 23) as f32)
                            .collect(),
                    )
                })
                .collect()
        })
        .collect();
    let session = Session::with_pool(pool.clone(), SessionConfig::for_writers(1, 4));
    let sink = rootio_par::tree::sink::BufferSink::new(schema.clone());
    let mut w = TreeWriter::attached(schema, sink, cfg, &session);
    for cols in &all_blocks {
        w.fill_columns(cols).unwrap();
    }
    w.flush().unwrap();
    let trace: Vec<Decision> = w.sizer_trace().to_vec();
    let waits = w.admission_waits();
    let (_, entries, stats) = w.close().unwrap();
    assert_eq!(entries, (block * blocks) as u64);
    (stats, waits, trace)
}

/// Satellite: under `Adaptive`, a narrow fast producer reaches a
/// steady cluster-size band within the run, its stall/compress ratio
/// improves over `Fixed` at the same starting size, and its
/// admission-wait count collapses (fewer, fatter clusters) — on a
/// private 8-worker pool.
#[test]
fn adaptive_converges_and_improves_stall_ratio_for_narrow_fast_producer() {
    let pool = Arc::new(Pool::new(8));
    let (fixed_stats, fixed_waits, _) = narrow_fast_run(&pool, ClusterSizing::Fixed);
    let adaptive = ClusterSizing::Adaptive(AdaptiveConfig {
        min_entries: 16,
        max_entries: 2048,
        hysteresis: 1,
        warmup: 2,
        ..Default::default()
    });
    let (adaptive_stats, adaptive_waits, trace) = narrow_fast_run(&pool, adaptive);

    // Converged: the size grew away from the starting 64 and the last
    // quarter of decisions sits in one steady band (at most one step
    // apart) — no late oscillation.
    assert!(
        adaptive_stats.sizing.last_entries >= 256,
        "expected >= 2 growth steps for a compression-bound narrow producer, got {:?}",
        adaptive_stats.sizing,
    );
    assert!(!trace.is_empty());
    let tail = &trace[trace.len() - (trace.len() / 4).max(1)..];
    let tail_min = tail.iter().map(|d| d.entries).min().unwrap();
    let tail_max = tail.iter().map(|d| d.entries).max().unwrap();
    assert!(
        tail_max <= tail_min * 2,
        "late oscillation wider than one step: {tail_min}..{tail_max} (trace {:?})",
        trace.iter().map(|d| d.entries).collect::<Vec<_>>(),
    );

    // The feedback collapsed admission churn: far fewer waiting
    // admissions than the fixed tiny-cluster run.
    assert!(
        adaptive_waits * 4 <= fixed_waits.max(4),
        "adaptive should wait far less often: {adaptive_waits} vs {fixed_waits} waits",
    );

    // And the producer's stall per unit of compression CPU improved:
    // the overhead that made the run compression-bound is gone.
    let ratio = |s: &WriteStats| {
        s.stall.as_secs_f64() / s.compress.as_secs_f64().max(1e-9)
    };
    assert!(
        ratio(&adaptive_stats) <= ratio(&fixed_stats),
        "stall/compress ratio must improve: adaptive {:.3} (stall {:?} / compress {:?}) \
         vs fixed {:.3} (stall {:?} / compress {:?})",
        ratio(&adaptive_stats),
        adaptive_stats.stall,
        adaptive_stats.compress,
        ratio(&fixed_stats),
        fixed_stats.stall,
        fixed_stats.compress,
    );
}

/// Tentpole property (ISSUE 5): whatever cluster boundaries the
/// adaptive writer cut under the seed's schedule and whatever window
/// policy the plan draws (on-demand / fixed / adaptive band, random
/// coalescing gap), a prefetched streaming read decodes
/// entry-identical to the serial read — across codecs, worker counts,
/// uneven tails, and the empty/one-row trees — and every read-budget
/// slot returns, even for a stream abandoned mid-flight.
#[test]
fn prop_prefetched_stream_decodes_identical_under_window_perturbation() {
    stress(
        "prop_prefetched_stream_decodes_identical_under_window_perturbation",
        |g, plan| {
            let pool = Arc::new(Pool::new(plan.workers));
            for n_rows in [0usize, 1, plan.n_rows] {
                let rows: Vec<Row> = (0..n_rows).map(|_| g.row(&plan.schema)).collect();
                // Adaptive pipelined write: cluster cuts are
                // schedule-dependent under this seed's knobs.
                let be: BackendRef = Arc::new(MemBackend::new());
                let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
                let sink = FileSink::new(fw.clone(), plan.schema.len());
                let session = Session::with_pool(
                    pool.clone(),
                    SessionConfig {
                        max_inflight_clusters: plan.max_inflight,
                        ..Default::default()
                    },
                );
                let cfg = WriterConfig {
                    basket_entries: plan.basket_entries,
                    compression: plan.compression,
                    flush: FlushMode::Pipelined,
                    granularity: FlushGranularity::Block,
                    max_inflight_clusters: plan.max_inflight,
                    sizing: plan.sizing,
                    selection: plan.selection.clone(),
                    layout: plan.layout,
                };
                let mut w = TreeWriter::attached(plan.schema.clone(), sink, cfg, &session);
                for row in &rows {
                    w.fill(row.clone()).unwrap();
                }
                let (sink, entries, _) = w.close().unwrap();
                let meta =
                    sink.into_meta("t".into(), plan.schema.clone(), entries).unwrap();
                fw.finish(&Directory { trees: vec![meta] }).unwrap();

                let reader =
                    TreeReader::open_first(Arc::new(FileReader::open(be).unwrap()))
                        .unwrap();
                let serial = reader.read_all().unwrap();
                let opts = PrefetchOptions {
                    window: plan.read_window,
                    coalesce_gap: plan.coalesce_gap,
                    ..Default::default()
                };

                // One stream...
                let mut s1 = reader.stream_in_session(&opts, &session).unwrap();
                let cols = s1.read_all_columns().unwrap();
                assert_eq!(
                    cols, serial,
                    "prefetched decode diverged (rows={n_rows}, window={:?}, gap={})",
                    plan.read_window, plan.coalesce_gap,
                );
                drop(s1);

                // ...then two concurrent streams on the shared budget.
                std::thread::scope(|s| {
                    let reader = &reader;
                    let opts = &opts;
                    let session = &session;
                    let serial = &serial;
                    let handles: Vec<_> = (0..2)
                        .map(|_| {
                            s.spawn(move || {
                                let mut st =
                                    reader.stream_in_session(opts, session).unwrap();
                                let cols = st.read_all_columns().unwrap();
                                assert_eq!(&cols, serial, "concurrent stream diverged");
                            })
                        })
                        .collect();
                    for h in handles {
                        h.join().unwrap();
                    }
                });

                // Projected-vs-full (paged dimension): a prefetched
                // read restricted to the plan's branch subset must
                // return exactly the serial decode of those branches,
                // in selection order, on either layout.
                if let Some(sel) = &plan.projection {
                    let proj = read_columns(
                        &reader,
                        &ReadOptions {
                            branches: Some(sel.clone()),
                            prefetch: Some(opts.clone()),
                            ..Default::default()
                        },
                    )
                    .unwrap();
                    assert_eq!(proj.columns.len(), sel.len());
                    for (k, &b) in sel.iter().enumerate() {
                        assert_eq!(
                            proj.columns[k], serial[b],
                            "projected read diverged on branch {b} \
                             (layout={:?}, seed={})",
                            plan.layout, plan.seed,
                        );
                    }
                }

                // A stream abandoned mid-flight must not leak slots.
                if n_rows > 0 {
                    let mut s3 = reader.stream_in_session(&opts, &session).unwrap();
                    let _ = s3.next().unwrap();
                    drop(s3);
                }
                session.drain().unwrap();
                assert_eq!(
                    session.stats().in_flight_read_windows,
                    0,
                    "read budget fully released (seed {})",
                    plan.seed,
                );
            }
        },
    );
}

/// Tentpole property (ISSUE 9): a chained scan over N same-schema
/// files decodes identically to the per-file serial reads
/// concatenated, and a predicate-pushed `scan_where` delivers exactly
/// the rows of that unpruned scan filtered row by row — across the
/// seed matrix's codecs, layouts, window policies, adaptive cluster
/// cuts, an empty file at a random chain slot, and with non-scalar
/// sibling columns (bytes, lists) riding the filter. The same rows
/// rewritten on a zone-less legacy wire (v1/v2 classic) must scan
/// identically with zero pages pruned, pinning that zone-map pruning
/// is a pure optimisation, never a semantic change.
#[test]
fn prop_chained_predicate_scan_equals_filtered_scan() {
    use rootio_par::cache::Predicate;
    use rootio_par::framework::chain::Chain;
    use rootio_par::serial::schema::{ColumnType, Field};
    use rootio_par::serial::value::Value;
    use rootio_par::tree::writer::Layout;

    stress("prop_chained_predicate_scan_equals_filtered_scan", |g, plan| {
        // Slot 0 carries a chain-global monotone f32 the predicate
        // targets; the seed's random typed fields follow.
        let mut fields = vec![Field::new("pred", ColumnType::F32)];
        fields.extend(plan.schema.fields.iter().cloned());
        let schema = Schema::new(fields);

        // Draw every file's rows up front so the v4 and legacy legs
        // write identical data.
        let mut file_rows: Vec<Vec<Row>> = Vec::new();
        let mut global = 0u64;
        for fi in 0..plan.chain_files {
            let n = if Some(fi) == plan.chain_empty {
                0
            } else {
                plan.n_rows / plan.chain_files + 1
            };
            let mut rows = Vec::with_capacity(n);
            for _ in 0..n {
                let mut row: Row = vec![Value::F32(global as f32)];
                row.extend(g.row(&plan.schema));
                rows.push(row);
                global += 1;
            }
            file_rows.push(rows);
        }
        let total = global;

        let pool = Arc::new(Pool::new(plan.workers));
        let session = Session::with_pool(
            pool,
            SessionConfig { max_inflight_clusters: plan.max_inflight, ..Default::default() },
        );
        let write_file = |rows: &[Row], version: u32, layout: Layout| -> BackendRef {
            let be: BackendRef = Arc::new(MemBackend::new());
            let fw = Arc::new(FileWriter::create_versioned(be.clone(), version).unwrap());
            let sink = FileSink::new(fw.clone(), schema.len());
            let cfg = WriterConfig {
                basket_entries: plan.basket_entries,
                compression: plan.compression,
                flush: FlushMode::Pipelined,
                granularity: FlushGranularity::Block,
                max_inflight_clusters: plan.max_inflight,
                sizing: plan.sizing,
                selection: plan.selection.clone(),
                layout,
            };
            let mut w = TreeWriter::attached(schema.clone(), sink, cfg, &session);
            for row in rows {
                w.fill(row.clone()).unwrap();
            }
            let (sink, entries, _) = w.close().unwrap();
            let meta = sink.into_meta("t".into(), schema.clone(), entries).unwrap();
            fw.finish(&Directory { trees: vec![meta] }).unwrap();
            be
        };
        let v4: Vec<BackendRef> = file_rows
            .iter()
            .map(|rows| write_file(rows, rootio_par::format::VERSION, plan.layout))
            .collect();
        let legacy: Vec<BackendRef> = file_rows
            .iter()
            .map(|rows| write_file(rows, plan.legacy_version, Layout::Classic))
            .collect();

        let opts = PrefetchOptions {
            window: plan.read_window,
            coalesce_gap: plan.coalesce_gap,
            ..Default::default()
        };
        let empty_cols = || -> Vec<ColumnData> {
            schema.fields.iter().map(|f| ColumnData::new(f.ty)).collect()
        };
        let concat = |parts: Vec<Vec<ColumnData>>| -> Vec<ColumnData> {
            let mut out = empty_cols();
            for part in parts {
                for (acc, col) in out.iter_mut().zip(part.iter()) {
                    acc.append(col).unwrap();
                }
            }
            out
        };

        // Unpruned chain scan == per-file serial reads concatenated.
        let chain = Chain::new(v4.clone());
        let mut parts = Vec::new();
        let all_rep = chain.scan(&opts, |b| parts.push(b.columns.clone())).unwrap();
        let base = concat(parts);
        let mut serial = empty_cols();
        for be in &v4 {
            let r = TreeReader::open_first(Arc::new(FileReader::open(be.clone()).unwrap()))
                .unwrap();
            for (acc, col) in serial.iter_mut().zip(r.read_all().unwrap().iter()) {
                acc.append(col).unwrap();
            }
        }
        assert_eq!(
            base, serial,
            "chain scan diverged from per-file serial reads (seed {})",
            plan.seed,
        );
        assert_eq!(all_rep.entries, total);

        // Predicate leg: pushed-down scan == row-filtered unpruned scan.
        let cutoff = total as f64 * 0.6;
        let pred = Predicate::ge(0, cutoff);
        let keep: Vec<bool> = (0..base[0].len())
            .map(|i| match base[0].get(i) {
                Some(Value::F32(v)) => pred.matches(f64::from(v)),
                _ => unreachable!("pred column is f32"),
            })
            .collect();
        let mut want = empty_cols();
        for (i, &k) in keep.iter().enumerate() {
            if k {
                for (w, c) in want.iter_mut().zip(base.iter()) {
                    w.push(c.get(i).unwrap()).unwrap();
                }
            }
        }
        let scan_where = |files: &[BackendRef]| {
            let chain = Chain::new(files.to_vec());
            let mut parts = Vec::new();
            let rep = chain
                .scan_where(pred, &opts, |b| parts.push(b.columns.clone()))
                .unwrap();
            (concat(parts), rep)
        };
        let (got, rep) = scan_where(&v4);
        assert_eq!(
            got, want,
            "pruned chain scan diverged from the filtered scan (seed {}, layout {:?})",
            plan.seed, plan.layout,
        );
        assert_eq!(
            rep.prefetch.bytes_selected + rep.prefetch.bytes_pruned,
            all_rep.prefetch.bytes_selected,
            "pruning must partition the unpruned plan's bytes (seed {})",
            plan.seed,
        );

        // Legacy zone-less leg: identical rows, nothing pruned.
        let (legacy_got, legacy_rep) = scan_where(&legacy);
        assert_eq!(
            legacy_got, want,
            "legacy v{} chain scan diverged (seed {})",
            plan.legacy_version, plan.seed,
        );
        assert_eq!(legacy_rep.prefetch.pages_pruned, 0, "no zones below wire v4");
        assert_eq!(legacy_rep.prefetch.bytes_pruned, 0);

        session.drain().unwrap();
        assert_eq!(session.stats().in_flight_clusters, 0);
    });
}

/// Satellite property (ISSUE 6): a seeded fraction of write ranges
/// blipping on their first attempt must be invisible after retry —
/// the pipelined adaptive write through a
/// `ResilientBackend(FaultyBackend(...))` stack decodes
/// entry-identical to a clean serial write under every schedule the
/// seed matrix perturbs, every injected fault is retried, and the
/// session budget drains with no leaked cluster slot.
#[test]
fn prop_write_faults_recover_to_identical_decode() {
    stress("prop_write_faults_recover_to_identical_decode", |g, plan| {
        let pool = Arc::new(Pool::new(plan.workers));
        let rows: Vec<Row> = (0..plan.n_rows).map(|_| g.row(&plan.schema)).collect();
        let clean_cfg = WriterConfig {
            basket_entries: plan.basket_entries,
            compression: plan.compression,
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let (clean_entries, clean) =
            write_and_decode(&plan.schema, &rows, clean_cfg, None, rootio_par::format::VERSION);

        let flaky = Arc::new(FaultyBackend::new(
            Arc::new(MemBackend::new()),
            FaultKind::Transient,
            FaultDirection::Writes,
            FaultPlan::SeededRate { seed: plan.seed, rate: plan.write_fault_rate },
        ));
        let res = Arc::new(ResilientBackend::new(
            flaky.clone() as BackendRef,
            ResilientConfig {
                retry: RetryPolicy {
                    base_backoff: Duration::from_micros(20),
                    max_backoff: Duration::from_micros(200),
                    seed: plan.seed,
                    ..RetryPolicy::default()
                },
                ..Default::default()
            },
        ));
        let be: BackendRef = res.clone();
        let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
        let sink = FileSink::new(fw.clone(), plan.schema.len());
        let session = Session::with_pool(
            pool,
            SessionConfig { max_inflight_clusters: plan.max_inflight, ..Default::default() },
        );
        let cfg = WriterConfig {
            basket_entries: plan.basket_entries,
            compression: plan.compression,
            flush: FlushMode::Pipelined,
            granularity: FlushGranularity::Block,
            max_inflight_clusters: plan.max_inflight,
            sizing: plan.sizing,
            selection: plan.selection.clone(),
            layout: plan.layout,
        };
        let mut w = TreeWriter::attached(plan.schema.clone(), sink, cfg, &session);
        for row in &rows {
            w.fill(row.clone()).unwrap();
        }
        let (sink, entries, _) = w.close().unwrap();
        let meta = sink.into_meta("t".into(), plan.schema.clone(), entries).unwrap();
        fw.finish(&Directory { trees: vec![meta] }).unwrap();
        session.drain().unwrap();
        assert_eq!(
            session.stats().in_flight_clusters,
            0,
            "budget fully released (seed {})",
            plan.seed,
        );

        assert_eq!(entries, clean_entries);
        let reader =
            TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        let cols = reader.read_all().unwrap();
        let got: Vec<Vec<u8>> = cols.iter().map(|c| c.encode()).collect();
        assert_eq!(
            got, clean,
            "faulted write decode diverged (seed {}, rate {})",
            plan.seed, plan.write_fault_rate,
        );
        if flaky.injected() > 0 {
            assert!(
                res.stats().write_retries >= flaky.injected(),
                "every transient write fault must be retried: {:?}",
                res.stats(),
            );
        }
    });
}

/// A sink whose `put_basket` always panics — the injected fault for
/// the release-on-panic regression.
struct PanickingSink;

impl BasketSink for PanickingSink {
    fn put_basket(&self, _meta: BasketMeta, _payload: PayloadBuf) -> Result<()> {
        panic!("injected basket failure mid-resize");
    }
}

/// Satellite regression: a flush task panicking while an *adaptive*
/// writer is between size steps must release its budget slot on
/// unwind — `close()` reports the failure, the session budget drains
/// to zero, and a subsequent writer admits immediately instead of
/// deadlocking on leaked slots.
#[test]
fn budget_slots_release_when_adaptive_writer_panics_mid_resize() {
    let pool = Arc::new(Pool::new(2));
    let session = Session::with_pool(pool, SessionConfig { max_inflight_clusters: 2, ..Default::default() });
    let schema = Schema::flat_f32("x", 2);
    let cfg = WriterConfig {
        basket_entries: 8,
        compression: Settings::new(Codec::Lz4r, 1),
        flush: FlushMode::Pipelined,
        granularity: FlushGranularity::Block,
        max_inflight_clusters: 2,
        sizing: ClusterSizing::Adaptive(AdaptiveConfig {
            min_entries: 4,
            max_entries: 64,
            hysteresis: 1,
            warmup: 0,
            ..Default::default()
        }),
        ..Default::default()
    };
    let mut w = TreeWriter::attached(schema.clone(), PanickingSink, cfg, &session);
    for i in 0..400 {
        let row: Row = (0..2).map(|_| rootio_par::serial::value::Value::F32(i as f32)).collect();
        if w.fill(row).is_err() {
            break; // failure may surface early; close() must still error
        }
    }
    assert!(w.close().is_err(), "panicked flush tasks must surface from close()");

    // No slot may leak: the budget drains and a fresh writer admits.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while session.stats().in_flight_clusters > 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "budget slots leaked after mid-resize panic: {:?}",
            session.stats(),
        );
        std::thread::yield_now();
    }
    let reg = session.register_writer(2);
    let guard = reg.try_acquire();
    assert!(guard.is_some(), "follow-up writer must admit after the panic released slots");
    drop(guard);
}

/// Virtual-time leg of the harness: random task graphs through the
/// deterministic simulator must respect dependencies, keep exclusive
/// units serialized, and never beat the critical-path lower bound —
/// under every seed's perturbation of widths and shapes.
#[test]
fn stress_simulated_schedules_respect_dependencies_and_bounds() {
    stress("stress_simulated_schedules_respect_dependencies_and_bounds", |g, plan| {
        let n = g.range(5, 60);
        let mut graph = Graph::new();
        for id in 0..n {
            let cost = Duration::from_micros(g.range(1, 5000) as u64);
            // up to 3 deps on earlier tasks
            let mut deps = Vec::new();
            if id > 0 {
                for _ in 0..g.range(0, 4) {
                    deps.push(g.range(0, id));
                }
                deps.sort_unstable();
                deps.dedup();
            }
            if g.range(0, 4) == 0 {
                let unit = format!("unit-{}", g.range(0, 3));
                graph.named(&unit, SpanKind::Write, cost, deps);
            } else {
                graph.pool(SpanKind::Compress, cost, deps);
            }
        }
        let r = simulate(&graph, plan.workers);
        assert_eq!(r.placements.len(), n, "every task placed exactly once");

        // Dependencies: a task starts only after all deps end.
        let mut end = vec![Duration::ZERO; n];
        for p in &r.placements {
            end[p.task] = p.end;
        }
        for p in &r.placements {
            for &d in &graph.tasks[p.task].deps {
                assert!(
                    p.start >= end[d],
                    "task {} started at {:?} before dep {} ended at {:?}",
                    p.task, p.start, d, end[d],
                );
            }
        }

        // Exclusive units never overlap.
        let mut by_unit: std::collections::HashMap<&str, Vec<(Duration, Duration)>> =
            std::collections::HashMap::new();
        for p in &r.placements {
            by_unit.entry(p.unit.as_str()).or_default().push((p.start, p.end));
        }
        for (unit, spans) in by_unit.iter_mut() {
            spans.sort();
            for w in spans.windows(2) {
                assert!(
                    w[1].0 >= w[0].1,
                    "unit {unit} overlaps: {:?} then {:?} (seed {})",
                    w[0], w[1], plan.seed,
                );
            }
        }

        // Makespan lower bounds: critical path and per-unit busy time.
        let mut path = vec![Duration::ZERO; n];
        for (id, t) in graph.tasks.iter().enumerate() {
            let dep_max =
                t.deps.iter().map(|&d| path[d]).max().unwrap_or(Duration::ZERO);
            path[id] = dep_max + t.cost;
        }
        let critical = path.iter().max().copied().unwrap_or_default();
        assert!(
            r.makespan >= critical,
            "makespan {:?} beats the critical path {:?}",
            r.makespan, critical,
        );
        for t in &graph.tasks {
            if let Place::Named(name) = &t.place {
                let busy: Duration = graph
                    .tasks
                    .iter()
                    .filter(|u| matches!(&u.place, Place::Named(m) if m == name))
                    .map(|u| u.cost)
                    .sum();
                assert!(r.makespan >= busy, "exclusive unit {name} overcommitted");
            }
        }
    });
}
