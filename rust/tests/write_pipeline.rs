//! Integration tests for the pipelined write path:
//! * pipelined / parallel, branch- / block-granularity flushes must be
//!   **byte-identical** to the serial writer across arbitrary schemas,
//!   uneven tail baskets, empty trees and every codec (the write-side
//!   mirror of the read equivalence property);
//! * N writers sharing one session produce bytes identical to the same
//!   writers run serially, across codecs;
//! * the shared budget is fair: a fat-basket writer stays within its
//!   share and narrow writers are never starved (and the scratch
//!   pool's drop counter stays bounded under the many-writer load);
//! * a panicking flush task must surface as an error from `close()`,
//!   never a hang or a cascading panic;
//! * the overlap is real: producer stall stays strictly below total
//!   compress time on a private pool.

mod common;

use std::sync::Arc;
use std::time::Duration;

use common::{property, Gen};
use rootio_par::compress::{Codec, Settings};
use rootio_par::error::{Error, Result};
use rootio_par::format::writer::FileWriter;
use rootio_par::format::Directory;
use rootio_par::imt::Pool;
use rootio_par::serial::schema::Schema;
use rootio_par::serial::value::{Row, Value};
use rootio_par::session::{Session, SessionConfig};
use rootio_par::storage::mem::MemBackend;
use rootio_par::storage::{Backend, BackendRef};
use rootio_par::tree::sink::{BasketMeta, BasketSink, FileSink, PayloadBuf};
use rootio_par::tree::writer::{
    FlushGranularity, FlushMode, TreeWriter, WriteStats, WriterConfig,
};

fn codecs() -> [Settings; 4] {
    [
        Settings::uncompressed(),
        Settings::new(Codec::Lz4r, 2),
        Settings::new(Codec::Lz4r, 7),
        Settings::new(Codec::Rzip, 3),
    ]
}

/// Write `rows` through a `FileSink` and return the finished file's
/// raw bytes plus the writer's pipeline stats. The writer attaches to
/// `session` when one is given (shared budget), else runs standalone
/// on `pool` / inline.
fn write_file_with(
    schema: &Schema,
    rows: &[Row],
    cfg: WriterConfig,
    pool: Option<Arc<Pool>>,
    session: Option<&Session>,
) -> (Vec<u8>, WriteStats) {
    let be: BackendRef = Arc::new(MemBackend::new());
    let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
    let sink = FileSink::new(fw.clone(), schema.len());
    let mut w = match session {
        Some(s) => TreeWriter::attached(schema.clone(), sink, cfg, s),
        None => TreeWriter::new(schema.clone(), sink, cfg),
    };
    if let Some(p) = pool {
        w = w.with_pool(p);
    }
    for row in rows {
        w.fill(row.clone()).unwrap();
    }
    let (sink, entries, stats) = w.close().unwrap();
    let meta = sink.into_meta("t".into(), schema.clone(), entries).unwrap();
    meta.check().unwrap(); // basket index invariant: gapless + monotone
    fw.finish(&Directory { trees: vec![meta] }).unwrap();
    let len = be.len().unwrap() as usize;
    let mut bytes = vec![0u8; len];
    be.read_at(0, &mut bytes).unwrap();
    (bytes, stats)
}

fn write_file(
    schema: &Schema,
    rows: &[Row],
    cfg: WriterConfig,
    pool: Option<Arc<Pool>>,
) -> (Vec<u8>, WriteStats) {
    write_file_with(schema, rows, cfg, pool, None)
}

/// The write-side equivalence property: every parallel flush mode and
/// granularity produces a file byte-identical to the serial writer,
/// across uneven tails (prime-ish basket sizes), single-basket trees,
/// the empty tree, and all codecs.
#[test]
fn prop_pipelined_write_bytes_match_serial() {
    let pool = Arc::new(Pool::new(4));
    property(20, |g| {
        let schema = g.schema(5);
        let n_rows = match g.range(0, 4) {
            0 => 0,                // empty tree
            1 => g.range(1, 12),   // single (partial) basket
            _ => g.range(40, 300), // many baskets, uneven tail
        };
        let rows: Vec<Row> = (0..n_rows).map(|_| g.row(&schema)).collect();
        let basket_entries = *g.choose(&[1usize, 3, 7, 13, 64, 500]);
        let compression = *g.choose(&codecs());
        let serial_cfg = WriterConfig {
            basket_entries,
            compression,
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let (serial, _) = write_file(&schema, &rows, serial_cfg, None);
        for flush in [FlushMode::Parallel, FlushMode::Pipelined] {
            for granularity in [FlushGranularity::Branch, FlushGranularity::Block] {
                let cfg = WriterConfig {
                    basket_entries,
                    compression,
                    flush,
                    granularity,
                    max_inflight_clusters: g.range(1, 4),
                    ..Default::default()
                };
                let (bytes, _) = write_file(&schema, &rows, cfg, Some(pool.clone()));
                assert_eq!(
                    bytes, serial,
                    "{flush:?}/{granularity:?} diverged from serial bytes \
                     (basket={basket_entries}, rows={n_rows})"
                );
            }
        }
    });
}

/// N writers under one shared session produce bytes identical to the
/// same writers run serially — across codecs, uneven baskets and
/// different per-writer schemas. Concurrency (shared pool, shared
/// fair-share budget) must be purely a scheduling property, never a
/// bytes property.
#[test]
fn shared_session_writers_byte_identical_to_serial_across_codecs() {
    let pool = Arc::new(Pool::new(4));
    for settings in codecs() {
        let mut g = Gen::new(0xC0FFEE ^ settings.level as u64);
        let writers: Vec<(Schema, Vec<Row>, usize)> = (0..4)
            .map(|_| {
                let schema = g.schema(4);
                let n_rows = g.range(30, 200);
                let rows: Vec<Row> = (0..n_rows).map(|_| g.row(&schema)).collect();
                let basket = *g.choose(&[7usize, 32, 100]);
                (schema, rows, basket)
            })
            .collect();
        // Ground truth: each writer alone, serial flush, no pool.
        let serial: Vec<Vec<u8>> = writers
            .iter()
            .map(|(schema, rows, basket)| {
                let cfg = WriterConfig {
                    basket_entries: *basket,
                    compression: settings,
                    flush: FlushMode::Serial,
                    ..Default::default()
                };
                write_file(schema, rows, cfg, None).0
            })
            .collect();
        // All four concurrently under one session.
        let session =
            Session::with_pool(pool.clone(), SessionConfig::for_writers(4, 2));
        let shared: Vec<Vec<u8>> = std::thread::scope(|s| {
            let handles: Vec<_> = writers
                .iter()
                .map(|(schema, rows, basket)| {
                    let session = &session;
                    let cfg = WriterConfig {
                        basket_entries: *basket,
                        compression: settings,
                        flush: FlushMode::Pipelined,
                        granularity: FlushGranularity::Block,
                        max_inflight_clusters: 2,
                        ..Default::default()
                    };
                    s.spawn(move || {
                        write_file_with(schema, rows, cfg, None, Some(session)).0
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (w, (a, b)) in serial.iter().zip(&shared).enumerate() {
            assert_eq!(
                a, b,
                "writer {w} under codec {:?} diverged from its serial bytes",
                settings
            );
        }
        assert_eq!(session.stats().in_flight_clusters, 0);
    }
}

/// Fairness under a shared budget: one fat-basket writer and three
/// narrow writers. The budget's fair share must cap the fat writer's
/// in-flight clusters (deterministic invariant), no narrow writer may
/// be starved for the duration of the run, and the scratch pool's
/// drop counter stays bounded (the eviction/high-water policy recycles
/// rather than discards).
#[test]
fn fat_writer_does_not_starve_narrow_writers_on_shared_budget() {
    let pool = Arc::new(Pool::new(3));
    // limit 4 over 4 registered writers -> fair share 1 each.
    let session = Session::with_pool(pool, SessionConfig { max_inflight_clusters: 4, ..Default::default() });
    let drops_before = rootio_par::compress::pool::stats().drops;

    let fat_schema = Schema::flat_f32("fat", 1);
    let fat_cfg = WriterConfig {
        basket_entries: 16_384,
        compression: Settings::new(Codec::Rzip, 6),
        flush: FlushMode::Pipelined,
        granularity: FlushGranularity::Block,
        max_inflight_clusters: 4,
        ..Default::default()
    };
    let narrow_schema = Schema::flat_f32("n", 2);
    let narrow_cfg = WriterConfig {
        basket_entries: 256,
        compression: Settings::new(Codec::Lz4r, 1),
        flush: FlushMode::Pipelined,
        granularity: FlushGranularity::Block,
        max_inflight_clusters: 2,
        ..Default::default()
    };

    // Register every writer BEFORE any runs, so the fair share is 1
    // for the whole run (deterministic).
    let mk_writer = |schema: &Schema, cfg: &WriterConfig| {
        let be: BackendRef = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be).unwrap());
        let sink = FileSink::new(fw, schema.len());
        TreeWriter::attached(schema.clone(), sink, cfg.clone(), &session)
    };
    let mut fat_writer = mk_writer(&fat_schema, &fat_cfg);
    let mut narrow: Vec<_> =
        (0..3).map(|_| mk_writer(&narrow_schema, &narrow_cfg)).collect();
    assert_eq!(fat_writer.admission_fair_share(), 1);

    let t0 = std::time::Instant::now();
    let mut g = Gen::new(77);
    let fat_rows: Vec<Row> =
        (0..6 * 16_384).map(|_| vec![Value::F32(g.f32())]).collect();
    let narrow_rows: Vec<Row> = (0..4 * 256)
        .map(|_| vec![Value::F32(g.f32()), Value::F32(g.f32())])
        .collect();

    let (fat_high_water, fat_stats) = std::thread::scope(|s| {
        let fat_handle = s.spawn(|| {
            for row in &fat_rows {
                fat_writer.fill(row.clone()).unwrap();
            }
            let hw = fat_writer.admission_high_water();
            let (_, entries, stats) = fat_writer.close().unwrap();
            assert_eq!(entries, 6 * 16_384);
            (hw, stats)
        });
        let narrow_handles: Vec<_> = narrow
            .iter_mut()
            .map(|w| {
                let rows = &narrow_rows;
                s.spawn(move || {
                    for row in rows {
                        w.fill(row.clone()).unwrap();
                    }
                    w.flush().unwrap();
                })
            })
            .collect();
        for h in narrow_handles {
            h.join().unwrap();
        }
        fat_handle.join().unwrap()
    });
    let wall = t0.elapsed();

    // Deterministic fairness invariant: with share 1, the fat writer
    // never held more than one cluster in flight.
    assert!(
        fat_high_water <= 1,
        "fat writer exceeded its fair share: high water {fat_high_water}"
    );
    assert!(fat_stats.baskets > 0);

    // Liveness: every narrow writer finished while the fat writer was
    // still in flight or shortly after — none was starved for the
    // whole run (a starved writer's stall would approach the wall).
    let mut narrow_entries = 0u64;
    for w in narrow.drain(..) {
        let (_, entries, stats) = w.close().unwrap();
        narrow_entries += entries;
        assert!(
            stats.stall.as_secs_f64() < 0.8 * wall.as_secs_f64() + 0.25,
            "narrow writer stalled {:?} of a {:?} run — starvation",
            stats.stall,
            wall,
        );
    }
    assert_eq!(narrow_entries, 3 * 4 * 256);

    // Scratch pool: the many-writer load must not translate into an
    // unbounded drop count (eviction recycles instead). The counter is
    // global, so allow head-room for concurrently-running tests.
    let drops_after = rootio_par::compress::pool::stats().drops;
    assert!(
        drops_after - drops_before < 1024,
        "scratch pool dropped {} buffers during the run",
        drops_after - drops_before
    );
}

/// A sink whose `put_basket` always panics — the injected fault for
/// the poisoned-task test.
struct PanickingSink;

impl BasketSink for PanickingSink {
    fn put_basket(&self, _meta: BasketMeta, _payload: PayloadBuf) -> Result<()> {
        panic!("injected basket failure");
    }
}

/// A panicking flush task must be caught by the task group and
/// reported by `close()` as an error — not hang the join, not unwind
/// into the producer.
#[test]
fn panicking_flush_task_surfaces_as_error_from_close() {
    let pool = Arc::new(Pool::new(2));
    let schema = Schema::flat_f32("x", 3);
    let cfg = WriterConfig {
        basket_entries: 16,
        compression: Settings::new(Codec::Lz4r, 1),
        flush: FlushMode::Pipelined,
        granularity: FlushGranularity::Block,
        max_inflight_clusters: 2,
        ..Default::default()
    };
    let mut w = TreeWriter::new(schema.clone(), PanickingSink, cfg).with_pool(pool);
    for i in 0..200 {
        let row: Row = (0..3).map(|_| Value::F32(i as f32)).collect();
        w.fill(row).unwrap();
    }
    match w.close() {
        Err(Error::Sync(_)) => {} // the expected abort path
        Err(other) => panic!("expected Error::Sync, got: {other}"),
        Ok(_) => panic!("close() must fail when flush tasks panicked"),
    }
}

/// A sink that *returns* errors (no panic): the failure must propagate
/// to the producer via fill/close instead of being dropped.
struct FailingSink;

impl BasketSink for FailingSink {
    fn put_basket(&self, _meta: BasketMeta, _payload: PayloadBuf) -> Result<()> {
        Err(Error::Codec("injected sink failure".into()))
    }
}

#[test]
fn failing_sink_error_reaches_the_producer() {
    let pool = Arc::new(Pool::new(2));
    let schema = Schema::flat_f32("x", 2);
    let cfg = WriterConfig {
        basket_entries: 8,
        compression: Settings::uncompressed(),
        flush: FlushMode::Pipelined,
        granularity: FlushGranularity::Block,
        max_inflight_clusters: 1,
        ..Default::default()
    };
    let mut w = TreeWriter::new(schema, FailingSink, cfg).with_pool(pool);
    let mut fill_failed = false;
    for i in 0..500 {
        let row: Row = vec![Value::F32(i as f32), Value::F32(-(i as f32))];
        if w.fill(row).is_err() {
            fill_failed = true;
            break;
        }
    }
    if !fill_failed {
        assert!(w.close().is_err(), "sink failure must surface by close()");
    }
}

/// Overlap is real, not just decomposition: on a private 2-worker
/// pool the producer's stall time stays strictly below the total
/// compress CPU (earlier clusters compress while later ones fill and
/// the close join only waits out the tail at 2-way parallelism).
#[test]
fn pipelined_write_overlaps_producer_and_compression() {
    let pool = Arc::new(Pool::new(2));
    let schema = Schema::flat_f32("x", 4);
    let cfg = WriterConfig {
        basket_entries: 512,
        compression: Settings::new(Codec::Rzip, 6),
        flush: FlushMode::Pipelined,
        granularity: FlushGranularity::Block,
        max_inflight_clusters: 4,
        ..Default::default()
    };
    let mut g = Gen::new(42);
    let rows: Vec<Row> = (0..8192)
        .map(|_| (0..4).map(|_| Value::F32(g.f32())).collect())
        .collect();
    let (_, stats) = write_file(&schema, &rows, cfg, Some(pool));
    assert!(stats.baskets > 0);
    assert!(stats.compress > Duration::ZERO);
    assert!(
        stats.stall < stats.compress,
        "producer stall ({:?}) must stay strictly below total compress time ({:?})",
        stats.stall,
        stats.compress,
    );
}
