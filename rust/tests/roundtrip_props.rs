//! Property-based integration tests over coordinator invariants:
//! arbitrary schemas, row batches, codecs, basket sizes and thread
//! counts must round-trip through write → file → read; the merger must
//! preserve the multiset of entries; hadd(serial) ≡ hadd(parallel);
//! the basket index must stay gapless and monotone.

mod common;

use std::sync::Arc;

use common::{property, Gen};
use rootio_par::compress::{self, Codec, Settings};
use rootio_par::coordinator::read::{read_columns, Granularity, ReadOptions};
use rootio_par::format::reader::FileReader;
use rootio_par::format::writer::FileWriter;
use rootio_par::format::Directory;
use rootio_par::hadd::{hadd, HaddOptions};
use rootio_par::merger::{MergerConfig, TBufferMerger};
use rootio_par::serial::schema::Schema;
use rootio_par::serial::value::{Row, Value};
use rootio_par::storage::mem::MemBackend;
use rootio_par::storage::BackendRef;
use rootio_par::tree::reader::TreeReader;
use rootio_par::tree::sink::FileSink;
use rootio_par::tree::writer::{FlushMode, TreeWriter, WriterConfig};

fn codecs() -> [Settings; 4] {
    [
        Settings::uncompressed(),
        Settings::new(Codec::Lz4r, 2),
        Settings::new(Codec::Lz4r, 7),
        Settings::new(Codec::Rzip, 3),
    ]
}

fn write_rows(
    schema: &Schema,
    rows: &[Row],
    cfg: WriterConfig,
) -> (Arc<FileReader>, BackendRef) {
    let be: BackendRef = Arc::new(MemBackend::new());
    let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
    let sink = FileSink::new(fw.clone(), schema.len());
    let mut w = TreeWriter::new(schema.clone(), sink, cfg);
    for row in rows {
        w.fill(row.clone()).unwrap();
    }
    let (sink, entries, _) = w.close().unwrap();
    let meta = sink.into_meta("t".into(), schema.clone(), entries).unwrap();
    meta.check().unwrap(); // basket index invariant: gapless + monotone
    fw.finish(&Directory { trees: vec![meta] }).unwrap();
    (Arc::new(FileReader::open(be.clone()).unwrap()), be)
}

#[test]
fn prop_write_read_roundtrip_any_schema() {
    property(40, |g| {
        let schema = g.schema(6);
        let n_rows = g.range(0, 400);
        let rows: Vec<Row> = (0..n_rows).map(|_| g.row(&schema)).collect();
        let cfg = WriterConfig {
            basket_entries: g.range(1, 128),
            compression: *g.choose(&codecs()),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let (reader, _) = write_rows(&schema, &rows, cfg);
        let tr = TreeReader::open_first(reader).unwrap();
        assert_eq!(tr.entries(), n_rows as u64);
        let cols = tr.read_all().unwrap();
        let back = tr.rows(&cols).unwrap();
        assert_eq!(back, rows);
    });
}

#[test]
fn prop_parallel_read_equals_serial_read() {
    property(15, |g| {
        let schema = g.schema(8);
        let rows: Vec<Row> = (0..g.range(50, 300)).map(|_| g.row(&schema)).collect();
        let cfg = WriterConfig {
            basket_entries: g.range(8, 64),
            compression: *g.choose(&codecs()),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let (reader, _) = write_rows(&schema, &rows, cfg);
        let tr = TreeReader::open_first(reader).unwrap();
        let serial =
            read_columns(&tr, &ReadOptions { force_serial: true, ..Default::default() })
                .unwrap();
        rootio_par::imt::enable(g.range(2, 6));
        let parallel = read_columns(&tr, &ReadOptions::default()).unwrap();
        rootio_par::imt::disable();
        assert_eq!(serial.columns, parallel.columns);
    });
}

/// Basket-granularity parallel reads must byte-match the serial
/// baseline across arbitrary schemas and deliberately uneven basket
/// layouts: trailing partial baskets (row count not a multiple of the
/// basket size), single-basket branches (basket >= rows), and the
/// empty tree.
#[test]
fn prop_basket_granularity_equals_serial_uneven_baskets() {
    property(20, |g| {
        let schema = g.schema(6);
        let n_rows = match g.range(0, 4) {
            0 => 0,                    // empty tree
            1 => g.range(1, 16),       // single (partial) basket
            _ => g.range(50, 400),     // many baskets, uneven tail
        };
        let rows: Vec<Row> = (0..n_rows).map(|_| g.row(&schema)).collect();
        // Prime-ish basket sizes make the final basket partial almost
        // always; basket >= rows exercises the single-basket branch.
        let basket_entries = *g.choose(&[1usize, 3, 7, 13, 64, 500]);
        let cfg = WriterConfig {
            basket_entries,
            compression: *g.choose(&codecs()),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let (reader, _) = write_rows(&schema, &rows, cfg);
        let tr = TreeReader::open_first(reader).unwrap();
        let serial =
            read_columns(&tr, &ReadOptions { force_serial: true, ..Default::default() })
                .unwrap();
        rootio_par::imt::enable(g.range(2, 6));
        let basket = read_columns(
            &tr,
            &ReadOptions { granularity: Granularity::Basket, ..Default::default() },
        )
        .unwrap();
        let branch = read_columns(
            &tr,
            &ReadOptions { granularity: Granularity::Branch, ..Default::default() },
        )
        .unwrap();
        rootio_par::imt::disable();
        assert_eq!(serial.columns, basket.columns, "basket granularity diverged");
        assert_eq!(serial.columns, branch.columns, "branch granularity diverged");
        assert_eq!(serial.raw_bytes, basket.raw_bytes);
        // decoded rows reassemble in entry order
        assert_eq!(tr.rows(&basket.columns).unwrap(), rows);
    });
}

#[test]
fn prop_merger_preserves_entry_multiset() {
    property(15, |g| {
        let schema = Schema::flat_f32("v", g.range(1, 4));
        let n_workers = g.range(1, 6);
        let per_worker = g.range(1, 200);
        let be: BackendRef = Arc::new(MemBackend::new());
        let merger = TBufferMerger::create(
            be.clone(),
            schema.clone(),
            MergerConfig {
                tree_name: "t".into(),
                queue_depth: g.range(1, 4),
                writer: WriterConfig {
                    basket_entries: g.range(1, 64),
                    compression: *g.choose(&codecs()),
                    flush: FlushMode::Serial,
                    ..Default::default()
                },
            },
        )
        .unwrap();
        std::thread::scope(|s| {
            for w in 0..n_workers {
                let mut f = merger.get_file();
                let schema = &schema;
                s.spawn(move || {
                    for i in 0..per_worker {
                        let row: Row = schema
                            .fields
                            .iter()
                            .map(|_| Value::F32((w * 10_000 + i) as f32))
                            .collect();
                        f.fill(row).unwrap();
                    }
                    f.write().unwrap();
                });
            }
        });
        let stats = merger.close().unwrap();
        assert_eq!(stats.entries, (n_workers * per_worker) as u64);

        let tr = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        let cols = tr.read_all().unwrap();
        let mut got: Vec<u32> = (0..tr.entries() as usize)
            .map(|i| match cols[0].get(i).unwrap() {
                Value::F32(v) => v as u32,
                _ => unreachable!(),
            })
            .collect();
        got.sort();
        let mut want: Vec<u32> = (0..n_workers)
            .flat_map(|w| (0..per_worker).map(move |i| (w * 10_000 + i) as u32))
            .collect();
        want.sort();
        assert_eq!(got, want);
    });
}

#[test]
fn prop_hadd_parallel_equals_serial() {
    property(10, |g| {
        let schema = g.schema(4);
        let n_files = g.range(1, 5);
        let inputs: Vec<BackendRef> = (0..n_files)
            .map(|_| {
                let rows: Vec<Row> = (0..g.range(1, 120)).map(|_| g.row(&schema)).collect();
                let cfg = WriterConfig {
                    basket_entries: g.range(4, 64),
                    compression: *g.choose(&codecs()),
                    flush: FlushMode::Serial,
            ..Default::default()
                };
                write_rows(&schema, &rows, cfg).1
            })
            .collect();
        let serial_out: BackendRef = Arc::new(MemBackend::new());
        let opts = HaddOptions { parallel: false, tree: Some("t".into()) };
        hadd(serial_out.clone(), &inputs, &opts).unwrap();
        rootio_par::imt::enable(3);
        let par_out: BackendRef = Arc::new(MemBackend::new());
        hadd(par_out.clone(), &inputs, &HaddOptions { parallel: true, tree: Some("t".into()) })
            .unwrap();
        rootio_par::imt::disable();

        let dump = |be: BackendRef| {
            let tr = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
            let cols = tr.read_all().unwrap();
            tr.rows(&cols).unwrap()
        };
        assert_eq!(dump(serial_out), dump(par_out));
    });
}

/// Variable-length columns must round-trip through both layouts at
/// the shapes that historically break offset encodings: a tree where
/// every collection is empty (offset column is all-equal), a single
/// entry holding one huge collection (element page much larger than
/// its offset page), wildly uneven nesting, and the zero-entry tree.
#[test]
fn prop_variable_length_roundtrip_edge_shapes() {
    use rootio_par::tree::writer::Layout;
    property(24, |g| {
        use rootio_par::serial::schema::{ColumnType, Field};
        let schema = Schema::new(vec![
            Field::new("pt", ColumnType::F32),
            Field::new("hits", ColumnType::ListF32),
        ]);
        let rows: Vec<Row> = match g.range(0, 4) {
            // zero-entry tree
            0 => vec![],
            // every collection empty: offset column carries no motion
            1 => (0..g.range(1, 150))
                .map(|i| vec![Value::F32(i as f32), Value::ListF32(vec![])])
                .collect(),
            // one entry, one huge collection
            2 => vec![vec![
                Value::F32(1.5),
                Value::ListF32((0..g.range(2_000, 20_000)).map(|k| k as f32 * 0.5).collect()),
            ]],
            // uneven nesting: empties interleaved with large bursts
            _ => (0..g.range(20, 200))
                .map(|i| {
                    let len = match i % 5 {
                        0 => 0,
                        4 => g.range(50, 400),
                        _ => g.range(0, 6),
                    };
                    vec![
                        Value::F32(i as f32),
                        Value::ListF32((0..len).map(|k| (i * 31 + k) as f32).collect()),
                    ]
                })
                .collect(),
        };
        let compression = *g.choose(&codecs());
        let layouts = [
            Layout::Classic,
            Layout::Paged { page_entries: g.range(1, 96) },
        ];
        for layout in layouts {
            let cfg = WriterConfig {
                basket_entries: g.range(1, 128),
                compression,
                flush: FlushMode::Serial,
                layout,
                ..Default::default()
            };
            let (reader, _) = write_rows(&schema, &rows, cfg);
            let tr = TreeReader::open_first(reader).unwrap();
            assert_eq!(tr.entries(), rows.len() as u64);
            let cols = tr.read_all().unwrap();
            assert_eq!(tr.rows(&cols).unwrap(), rows);
        }
    });
}

/// v3 paged files over arbitrary schemas (lists included) and random
/// page/cluster geometry must decode identically to the classic layout
/// of the same rows — full reads and projected reads alike.
#[test]
fn prop_paged_layout_matches_classic_any_geometry() {
    use rootio_par::tree::writer::Layout;
    property(16, |g| {
        let schema = g.schema(6);
        let rows: Vec<Row> = (0..g.range(0, 300)).map(|_| g.row(&schema)).collect();
        let compression = *g.choose(&codecs());
        let basket_entries = g.range(1, 100);
        let classic = WriterConfig {
            basket_entries,
            compression,
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let paged = WriterConfig {
            layout: Layout::Paged { page_entries: g.range(1, 80) },
            ..classic.clone()
        };
        let (classic_reader, _) = write_rows(&schema, &rows, classic);
        let (paged_reader, _) = write_rows(&schema, &rows, paged);
        let ct = TreeReader::open_first(classic_reader).unwrap();
        let pt = TreeReader::open_first(paged_reader).unwrap();
        assert_eq!(ct.read_all().unwrap(), pt.read_all().unwrap());
        // Projected read on the paged file: any random branch subset.
        if !schema.fields.is_empty() {
            let n_sel = g.range(1, schema.len() + 1);
            let mut sel: Vec<usize> = (0..schema.len()).collect();
            for i in (1..sel.len()).rev() {
                sel.swap(i, g.range(0, i + 1));
            }
            sel.truncate(n_sel);
            let proj = read_columns(
                &pt,
                &ReadOptions { branches: Some(sel.clone()), ..Default::default() },
            )
            .unwrap();
            let full = ct.read_all().unwrap();
            for (k, &b) in sel.iter().enumerate() {
                assert_eq!(proj.columns[k], full[b], "projected branch {b} diverged");
            }
        }
    });
}

#[test]
fn prop_codec_container_roundtrips_arbitrary_bytes() {
    property(60, |g| {
        // Mix random and structured payloads of varied sizes.
        let n = g.range(0, 60_000);
        let data: Vec<u8> = if g.bool() {
            (0..n).map(|_| g.u32() as u8).collect()
        } else {
            (0..n).map(|i| ((i / g.range(1, 17)) % 251) as u8).collect()
        };
        let settings = *g.choose(&codecs());
        let packed = compress::compress(settings, &data);
        assert_eq!(compress::decompress(&packed).unwrap(), data);
        // blocks scan cleanly and account for all payload bytes
        let blocks = compress::scan_blocks(&packed).unwrap();
        let total: usize = blocks.iter().map(|b| b.raw_len).sum();
        assert_eq!(total, data.len());
    });
}

#[test]
fn prop_crc_detects_single_bit_flips() {
    property(40, |g| {
        let n = g.range(1, 5000);
        let data: Vec<u8> = (0..n).map(|_| g.u32() as u8).collect();
        let crc = compress::crc32(&data);
        let mut flipped = data.clone();
        let bit = g.range(0, n * 8);
        flipped[bit / 8] ^= 1 << (bit % 8);
        assert_ne!(compress::crc32(&flipped), crc);
    });
}
