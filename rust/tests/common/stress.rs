//! Seeded deterministic concurrency-stress harness.
//!
//! Adaptive cluster sizing makes cluster boundaries schedule-dependent,
//! so the write-equivalence suite can no longer rely on byte-identity
//! alone — it needs *many* schedules, each reproducible. This harness
//! perturbs the schedule-shaping knobs (worker count, codec mix,
//! basket size, in-flight cap, uneven entry tails, adaptive band) from
//! one seed, runs the property under every seed of a pinned matrix,
//! and on failure prints the exact reproduction command:
//!
//! ```text
//! STRESS_SEEDS=<seed> cargo test --test stress <test-name>
//! ```
//!
//! The matrix is pinned in CI via the `STRESS_SEEDS` env var (comma
//! separated); locally it defaults to seeds 0..6. Everything derived
//! from the seed goes through the library's own SplitMix PRNG (via
//! [`super::Gen`]), so a plan is a pure function of its seed.

#![allow(dead_code)]

use rootio_par::cache::{WindowConfig, WindowPolicy};
use rootio_par::compress::select::{CodecSelection, SelectConfig};
use rootio_par::compress::{Codec, Settings};
use rootio_par::serial::schema::Schema;
use rootio_par::tree::sizer::{AdaptiveConfig, ClusterSizing};
use rootio_par::tree::writer::Layout;

use super::Gen;

/// One seed's worth of schedule perturbation: every knob that shapes
/// task interleavings in the write pipeline.
pub struct StressPlan {
    pub seed: u64,
    /// Private pool width for the run (1..=8 — odd widths included on
    /// purpose, they produce the ugliest steals).
    pub workers: usize,
    /// Codec mix: none / fast LZ / slow LZ / deflate-style at two
    /// levels.
    pub compression: Settings,
    /// Starting cluster size (deliberately includes degenerate 1).
    pub basket_entries: usize,
    /// Session in-flight cluster cap.
    pub max_inflight: usize,
    /// Adaptive band derived from `basket_entries` with randomised
    /// hysteresis/warmup — always adaptive, so every seed exercises
    /// the resize path.
    pub sizing: ClusterSizing,
    /// Row count with an uneven tail (never a multiple of the basket).
    pub n_rows: usize,
    /// Random typed schema (1..=4 branches — narrow trees).
    pub schema: Schema,
    /// Read-side prefetch window policy drawn per seed (ISSUE 5): the
    /// streaming re-read of every written file runs under this —
    /// on-demand, fixed, or an adaptive band with randomised
    /// hysteresis/warmup — so window resizing is perturbed alongside
    /// the write-side schedule.
    pub read_window: WindowPolicy,
    /// Stored-range gap the prefetcher bridges when coalescing (0
    /// forces strict adjacency).
    pub coalesce_gap: u32,
    /// Codec-mix dimension (ISSUE 7): half the matrix writes with
    /// per-column adaptive codec selection (randomised probe length
    /// and re-probe interval), so every decoded-identity property also
    /// covers trees whose branches mix codecs basket by basket; the
    /// other half keeps the global `compression` for the historical
    /// path.
    pub selection: CodecSelection,
    /// Write-side transient-fault rate (ISSUE 6): the fraction of
    /// distinct write ranges whose *first* attempt blips
    /// ([`rootio_par::storage::fault::FaultPlan::SeededRate`] — retries
    /// always pass, so recovery is deterministic under any schedule).
    /// 0 keeps the device healthy; half the matrix draws a fault rate.
    pub write_fault_rate: f64,
    /// Cluster-layout dimension: half the matrix writes the classic
    /// one-basket-per-branch layout, half the paged v3 layout at a
    /// randomised page size (degenerate 1-row pages included) — so
    /// every decoded-identity property also covers per-column page
    /// sealing under schedule perturbation.
    pub layout: Layout,
    /// Projection-pushdown dimension: when set, the read side repeats
    /// the read restricted to this branch subset and checks it
    /// column-for-column against the full decode (projected-vs-full).
    pub projection: Option<Vec<usize>>,
    /// Chain dimension (ISSUE 9): how many same-schema files the
    /// chained-scan property strings into one stream (single-file
    /// chains included).
    pub chain_files: usize,
    /// Chain slot written with zero rows (None = every file populated)
    /// — the empty-file-mid-chain regression rides every seed that
    /// draws it, at a random position.
    pub chain_empty: Option<usize>,
    /// Zone-less legacy wire version (1 or 2) for the chain property's
    /// third leg: the same rows rewritten below the zone-map wire must
    /// predicate-scan identically with zero pages pruned.
    pub legacy_version: u32,
}

impl StressPlan {
    /// Derive the plan for `seed` from `g` (which must itself be
    /// seeded from `seed` — [`stress`] does both).
    pub fn draw(g: &mut Gen, seed: u64) -> StressPlan {
        let codecs = [
            Settings::uncompressed(),
            Settings::new(Codec::Lz4r, 2),
            Settings::new(Codec::Lz4r, 7),
            Settings::new(Codec::Rzip, 3),
            Settings::new(Codec::Rzip, 6),
        ];
        let basket_entries = *g.choose(&[1usize, 3, 13, 64, 257]);
        let band = 1usize << g.range(1, 4); // x2..x8 either side
        let sizing = ClusterSizing::Adaptive(AdaptiveConfig {
            min_entries: (basket_entries / band).max(1),
            max_entries: basket_entries.saturating_mul(band).max(2),
            hysteresis: g.range(1, 3) as u32,
            warmup: g.range(0, 3) as u32,
            ..Default::default()
        });
        // Uneven tail by construction: a prime-ish row count.
        let n_rows = g.range(40, 400) * 2 + 1;
        let read_window = match g.range(0, 3) {
            0 => WindowPolicy::None,
            1 => WindowPolicy::Fixed(g.range(1, 9)),
            _ => WindowPolicy::Adaptive(WindowConfig {
                min_clusters: g.range(1, 3),
                max_clusters: g.range(3, 12),
                hysteresis: g.range(1, 3) as u32,
                warmup: g.range(0, 2) as u32,
                ..Default::default()
            }),
        };
        let selection = if g.range(0, 2) == 0 {
            CodecSelection::Global
        } else {
            CodecSelection::PerColumn(SelectConfig {
                probe_baskets: g.range(1, 3) as u32,
                reprobe_interval: *g.choose(&[0u32, 8, 64]),
                ..Default::default()
            })
        };
        let schema = g.schema(4);
        let layout = if g.bool() {
            Layout::Paged { page_entries: *g.choose(&[1usize, 7, 32, 128]) }
        } else {
            Layout::Classic
        };
        let projection = if g.bool() {
            let keep = g.range(1, schema.len() + 1);
            let mut sel: Vec<usize> = (0..schema.len()).collect();
            for i in (1..sel.len()).rev() {
                sel.swap(i, g.range(0, i + 1));
            }
            sel.truncate(keep);
            Some(sel)
        } else {
            None
        };
        let chain_files = g.range(1, 5);
        let chain_empty = if g.bool() { Some(g.range(0, chain_files)) } else { None };
        StressPlan {
            seed,
            workers: g.range(1, 9),
            compression: codecs[g.range(0, codecs.len())],
            basket_entries,
            max_inflight: g.range(1, 5),
            sizing,
            n_rows,
            schema,
            read_window,
            coalesce_gap: *g.choose(&[0u32, 64, 4096]),
            selection,
            write_fault_rate: *g.choose(&[0.0, 0.0, 0.15, 0.35]),
            layout,
            projection,
            chain_files,
            chain_empty,
            legacy_version: if g.bool() { 1 } else { 2 },
        }
    }
}

/// The pinned seed matrix: `STRESS_SEEDS="3,17,42"` (CI pins this),
/// else seeds 0..6.
pub fn seed_matrix() -> Vec<u64> {
    if let Ok(s) = std::env::var("STRESS_SEEDS") {
        let seeds: Vec<u64> = s.split(',').filter_map(|t| t.trim().parse().ok()).collect();
        if !seeds.is_empty() {
            return seeds;
        }
    }
    (0..6).collect()
}

/// Run `f` once per seed of the matrix with that seed's plan and a
/// generator to draw test data from. A failing seed aborts the test
/// with the reproduction command in the failure output.
pub fn stress(label: &str, f: impl Fn(&mut Gen, &StressPlan)) {
    for seed in seed_matrix() {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1));
            let plan = StressPlan::draw(&mut g, seed);
            f(&mut g, &plan);
        }));
        if let Err(e) = result {
            eprintln!(
                "stress '{label}' failed at seed {seed}; reproduce with:\n  \
                 STRESS_SEEDS={seed} cargo test --test stress {label}"
            );
            std::panic::resume_unwind(e);
        }
    }
}
