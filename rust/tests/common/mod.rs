//! Shared test utilities: a minimal property-testing harness (the
//! environment has no proptest crate — see Cargo.toml), random data
//! generators built on the library's own SplitMix PRNG, and the seeded
//! concurrency-stress harness ([`stress`]).

pub mod stress;

use rootio_par::framework::dataset::SplitMix;
use rootio_par::serial::schema::{ColumnType, Field, Schema};
use rootio_par::serial::value::{Row, Value};

/// Deterministic random generator for property cases.
pub struct Gen {
    rng: SplitMix,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: SplitMix::new(seed) }
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + (self.u32() as usize) % (hi - lo)
    }

    pub fn f32(&mut self) -> f32 {
        // mix of magnitudes, no NaNs (Row equality)
        let u = self.rng.uniform();
        (u - 0.5) * 10f32.powi(self.range(0, 8) as i32 - 4)
    }

    pub fn bytes(&mut self, max_len: usize) -> Vec<u8> {
        let n = self.range(0, max_len + 1);
        (0..n).map(|_| self.u32() as u8).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.u32() & 1 == 1
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len())]
    }

    /// A variable-length f32 collection: usually short, sometimes
    /// empty, occasionally long — the nesting profile real event data
    /// has (most entries hold a few hits, a tail holds many).
    pub fn list_f32(&mut self, max_len: usize) -> Vec<f32> {
        let n = match self.range(0, 8) {
            0 | 1 => 0,
            7 => self.range(0, max_len + 1),
            _ => self.range(1, (max_len + 1).min(9).max(2)),
        };
        (0..n).map(|_| self.f32()).collect()
    }

    /// Random schema: 1..=max_fields typed fields (variable-length
    /// `list<f32>` columns included).
    pub fn schema(&mut self, max_fields: usize) -> Schema {
        let types = [
            ColumnType::I32,
            ColumnType::I64,
            ColumnType::F32,
            ColumnType::F64,
            ColumnType::U8,
            ColumnType::Bytes,
            ColumnType::ListF32,
        ];
        let n = self.range(1, max_fields + 1);
        Schema::new(
            (0..n).map(|i| Field::new(format!("f{i}"), *self.choose(&types))).collect(),
        )
    }

    /// A random row matching `schema`.
    pub fn row(&mut self, schema: &Schema) -> Row {
        schema
            .fields
            .iter()
            .map(|f| match f.ty {
                ColumnType::I32 => Value::I32(self.u32() as i32),
                ColumnType::I64 => {
                    Value::I64(((self.u32() as i64) << 32) | self.u32() as i64)
                }
                ColumnType::F32 => Value::F32(self.f32()),
                ColumnType::F64 => Value::F64(self.f32() as f64 * 1e3),
                ColumnType::U8 => Value::U8(self.u32() as u8),
                ColumnType::Bytes => Value::Bytes(self.bytes(24)),
                ColumnType::ListF32 => Value::ListF32(self.list_f32(40)),
            })
            .collect()
    }
}

/// Run `f` across `cases` deterministic seeds; failures report the seed.
/// (Not every test binary uses it — the stress suite has its own
/// seeded runner — hence the allow.)
#[allow(dead_code)]
pub fn property(cases: u64, f: impl Fn(&mut Gen)) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(seed * 0x9E3779B9 + 1);
            f(&mut g);
        }));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}
