//! Failure injection: random corruption of stored files must surface
//! as errors (checksum/format/codec), never panics or silent bad data.

mod common;

use std::sync::Arc;

use common::{property, Gen};
use rootio_par::compress::{Codec, Settings};
use rootio_par::format::reader::FileReader;
use rootio_par::format::writer::FileWriter;
use rootio_par::format::Directory;
use rootio_par::serial::value::Value;
use rootio_par::storage::mem::MemBackend;
use rootio_par::storage::{Backend, BackendRef};
use rootio_par::tree::reader::TreeReader;
use rootio_par::tree::sink::FileSink;
use rootio_par::tree::writer::{FlushMode, TreeWriter, WriterConfig};

fn build_file(g: &mut Gen) -> BackendRef {
    let schema = g.schema(4);
    let be: BackendRef = Arc::new(MemBackend::new());
    let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
    let sink = FileSink::new(fw.clone(), schema.len());
    let cfg = WriterConfig {
        basket_entries: g.range(4, 40),
        compression: if g.bool() {
            Settings::new(Codec::Rzip, 3)
        } else {
            Settings::new(Codec::Lz4r, 3)
        },
        flush: FlushMode::Serial,
        ..Default::default()
    };
    let mut w = TreeWriter::new(schema.clone(), sink, cfg);
    for _ in 0..g.range(10, 200) {
        let row = g.row(&schema);
        w.fill(row).unwrap();
    }
    let (sink, entries, _) = w.close().unwrap();
    let meta = sink.into_meta("t".into(), schema, entries).unwrap();
    fw.finish(&Directory { trees: vec![meta] }).unwrap();
    be
}

/// Read everything; any Err is acceptable, panics are not. Returns
/// whether every stage succeeded (i.e. corruption went undetected).
fn try_full_read(be: BackendRef) -> bool {
    let Ok(file) = FileReader::open(be) else { return false };
    let Ok(reader) = TreeReader::open_first(Arc::new(file)) else { return false };
    match reader.read_all() {
        Ok(cols) => reader.rows(&cols).is_ok(),
        Err(_) => false,
    }
}

#[test]
fn random_byte_corruption_never_panics() {
    property(60, |g| {
        let be = build_file(g);
        let len = be.len().unwrap() as usize;
        // corrupt 1..4 random bytes
        for _ in 0..g.range(1, 5) {
            let off = g.range(0, len);
            let b = g.u32() as u8;
            be.write_at(off as u64, &[b]).unwrap();
        }
        // must not panic; detection is expected but single-byte writes
        // can hit slack space (e.g. rewrite the same value)
        let _ = try_full_read(be);
    });
}

#[test]
fn payload_corruption_is_detected() {
    property(40, |g| {
        let be = build_file(g);
        let len = be.len().unwrap() as usize;
        // Flip a bit strictly inside the basket payload region
        // (after the 24-byte header, before the footer) — guaranteed
        // to be covered by a basket CRC.
        let file = FileReader::open(be.clone()).unwrap();
        let tree = &file.directory().trees[0];
        let br = &tree.branches[g.range(0, tree.branches.len())];
        let k = &br.baskets[g.range(0, br.baskets.len())];
        let off = k.offset + g.range(0, k.comp_len as usize) as u64;
        drop(file);
        let mut cur = [0u8; 1];
        be.read_at(off, &mut cur).unwrap();
        be.write_at(off, &[cur[0] ^ 0x40]).unwrap();
        let _ = len;
        assert!(
            !try_full_read(be),
            "bit flip inside a basket payload must be detected by CRC"
        );
    });
}

#[test]
fn truncated_files_are_rejected() {
    property(25, |g| {
        let be = build_file(g);
        let len = be.len().unwrap() as usize;
        let keep = g.range(0, len);
        let mut data = vec![0u8; len];
        be.read_at(0, &mut data).unwrap();
        let truncated: BackendRef = Arc::new(MemBackend::from_vec(data[..keep].to_vec()));
        assert!(
            !try_full_read(truncated),
            "truncation to {keep}/{len} bytes must not read back cleanly"
        );
    });
}

#[test]
fn header_corruption_is_rejected() {
    let mut g = Gen::new(7);
    let be = build_file(&mut g);
    for off in [0u64, 1, 4, 8, 16] {
        let mut cur = [0u8; 1];
        be.read_at(off, &mut cur).unwrap();
        be.write_at(off, &[cur[0] ^ 0xFF]).unwrap();
        assert!(!try_full_read(be.clone()), "header byte {off} corruption");
        be.write_at(off, &cur).unwrap(); // restore
        assert!(try_full_read(be.clone()), "restore at byte {off}");
    }
}
