//! Failure injection: random corruption of stored files must surface
//! as errors (checksum/format/codec), never panics or silent bad data.
//!
//! Beyond stored-bit corruption, the device itself misbehaves: reads
//! fail or short out mid-window (ISSUE 5), remote requests blip, stall
//! far past p99 or die for good (ISSUE 6). The [`FaultyBackend`] /
//! [`RemoteDevice`] tests below drive the prefetcher, the multi-writer
//! sink and `hadd` through those faults and require either full
//! recovery (byte-identical data) or one clean error — never a panic,
//! a hang, or a leaked session budget slot.

mod common;

use std::sync::Arc;
use std::time::Duration;

use common::{property, Gen};
use rootio_par::cache::PrefetchOptions;
use rootio_par::compress::{Codec, Settings};
use rootio_par::format::reader::FileReader;
use rootio_par::format::writer::FileWriter;
use rootio_par::format::Directory;
use rootio_par::imt::Pool;
use rootio_par::serial::schema::Schema;
use rootio_par::serial::value::Value;
use rootio_par::session::{Session, SessionConfig};
use rootio_par::storage::fault::{FaultDirection, FaultKind, FaultPlan, FaultyBackend};
use rootio_par::storage::mem::MemBackend;
use rootio_par::storage::remote::{RemoteConfig, RemoteDevice};
use rootio_par::storage::resilient::{
    HedgePolicy, ResilientBackend, ResilientConfig, RetryPolicy,
};
use rootio_par::storage::{Backend, BackendRef};
use rootio_par::tree::reader::TreeReader;
use rootio_par::tree::sink::FileSink;
use rootio_par::tree::writer::{FlushMode, TreeWriter, WriterConfig};

fn build_file(g: &mut Gen) -> BackendRef {
    let schema = g.schema(4);
    let be: BackendRef = Arc::new(MemBackend::new());
    let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
    let sink = FileSink::new(fw.clone(), schema.len());
    let cfg = WriterConfig {
        basket_entries: g.range(4, 40),
        compression: if g.bool() {
            Settings::new(Codec::Rzip, 3)
        } else {
            Settings::new(Codec::Lz4r, 3)
        },
        flush: FlushMode::Serial,
        ..Default::default()
    };
    let mut w = TreeWriter::new(schema.clone(), sink, cfg);
    for _ in 0..g.range(10, 200) {
        let row = g.row(&schema);
        w.fill(row).unwrap();
    }
    let (sink, entries, _) = w.close().unwrap();
    let meta = sink.into_meta("t".into(), schema, entries).unwrap();
    fw.finish(&Directory { trees: vec![meta] }).unwrap();
    be
}

/// Read everything; any Err is acceptable, panics are not. Returns
/// whether every stage succeeded (i.e. corruption went undetected).
fn try_full_read(be: BackendRef) -> bool {
    let Ok(file) = FileReader::open(be) else { return false };
    let Ok(reader) = TreeReader::open_first(Arc::new(file)) else { return false };
    match reader.read_all() {
        Ok(cols) => reader.rows(&cols).is_ok(),
        Err(_) => false,
    }
}

#[test]
fn random_byte_corruption_never_panics() {
    property(60, |g| {
        let be = build_file(g);
        let len = be.len().unwrap() as usize;
        // corrupt 1..4 random bytes
        for _ in 0..g.range(1, 5) {
            let off = g.range(0, len);
            let b = g.u32() as u8;
            be.write_at(off as u64, &[b]).unwrap();
        }
        // must not panic; detection is expected but single-byte writes
        // can hit slack space (e.g. rewrite the same value)
        let _ = try_full_read(be);
    });
}

#[test]
fn payload_corruption_is_detected() {
    property(40, |g| {
        let be = build_file(g);
        let len = be.len().unwrap() as usize;
        // Flip a bit strictly inside the basket payload region
        // (after the 24-byte header, before the footer) — guaranteed
        // to be covered by a basket CRC.
        let file = FileReader::open(be.clone()).unwrap();
        let tree = &file.directory().trees[0];
        let br = &tree.branches[g.range(0, tree.branches.len())];
        let k = &br.baskets[g.range(0, br.baskets.len())];
        let off = k.offset + g.range(0, k.comp_len as usize) as u64;
        drop(file);
        let mut cur = [0u8; 1];
        be.read_at(off, &mut cur).unwrap();
        be.write_at(off, &[cur[0] ^ 0x40]).unwrap();
        let _ = len;
        assert!(
            !try_full_read(be),
            "bit flip inside a basket payload must be detected by CRC"
        );
    });
}

#[test]
fn truncated_files_are_rejected() {
    property(25, |g| {
        let be = build_file(g);
        let len = be.len().unwrap() as usize;
        let keep = g.range(0, len);
        let mut data = vec![0u8; len];
        be.read_at(0, &mut data).unwrap();
        let truncated: BackendRef = Arc::new(MemBackend::from_vec(data[..keep].to_vec()));
        assert!(
            !try_full_read(truncated),
            "truncation to {keep}/{len} bytes must not read back cleanly"
        );
    });
}

#[test]
fn header_corruption_is_rejected() {
    let mut g = Gen::new(7);
    let be = build_file(&mut g);
    for off in [0u64, 1, 4, 8, 16] {
        let mut cur = [0u8; 1];
        be.read_at(off, &mut cur).unwrap();
        be.write_at(off, &[cur[0] ^ 0xFF]).unwrap();
        assert!(!try_full_read(be.clone()), "header byte {off} corruption");
        be.write_at(off, &cur).unwrap(); // restore
        assert!(try_full_read(be.clone()), "restore at byte {off}");
    }
}

/// Healthy streaming fixture shared by the device-fault tests below:
/// 2 F32 branches × `rows` rows at 64 per basket (one cluster per 64
/// rows), written through `inner`.
fn build_stream_file(inner: &BackendRef, rows: usize) {
    let schema = Schema::flat_f32("c", 2);
    let fw = Arc::new(FileWriter::create(inner.clone()).unwrap());
    let sink = FileSink::new(fw.clone(), 2);
    let cfg = WriterConfig {
        basket_entries: 64,
        compression: Settings::new(Codec::Lz4r, 2),
        flush: FlushMode::Serial,
        ..Default::default()
    };
    let mut w = TreeWriter::new(schema.clone(), sink, cfg);
    for i in 0..rows {
        w.fill(vec![Value::F32(i as f32), Value::F32(i as f32 * 0.5)]).unwrap();
    }
    let (sink, entries, _) = w.close().unwrap();
    let meta = sink.into_meta("t".into(), schema, entries).unwrap();
    fw.finish(&Directory { trees: vec![meta] }).unwrap();
}

/// Satellite (ISSUE 5, re-pointed at the promoted
/// [`rootio_par::storage::fault::FaultyBackend`] in ISSUE 6): a
/// failing or silently-short read mid-window must propagate as an
/// error through the prefetcher — no hang, no leaked read-budget
/// slot, the session still drains cleanly.
#[test]
fn prefetcher_surfaces_device_faults_without_hang_or_leaked_slots() {
    // Healthy 8-cluster file: 2 branches × 512 rows at 64 per basket.
    let inner: BackendRef = Arc::new(MemBackend::new());
    build_stream_file(&inner, 512);

    let pool = Arc::new(Pool::new(3));
    for short in [false, true] {
        // Open with an unlimited budget (however many reads the open
        // path needs), then arm the fault: 3 healthy window fetches,
        // a later window's fetch fails mid-stream while earlier
        // clusters are being consumed.
        let flaky = Arc::new(FaultyBackend::fail_reads_after(inner.clone(), i64::MAX, short));
        let be: BackendRef = flaky.clone();
        let reader =
            TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        flaky.arm(3);
        let session = Session::with_pool(pool.clone(), SessionConfig::default());
        let mut stream = reader
            .stream_in_session(&PrefetchOptions::fixed(2), &session)
            .unwrap();
        let mut consumed = 0usize;
        loop {
            match stream.next() {
                Ok(Some(_)) => consumed += 1,
                Ok(None) => panic!("stream must fail before the end (short={short})"),
                Err(_) => break, // Io or checksum Format — both are clean surfaces
            }
        }
        assert!(
            consumed < 8,
            "the fault must land mid-stream, yet {consumed}/8 clusters decoded"
        );
        assert!(
            stream.next().is_err(),
            "a failed stream must stay failed (short={short})"
        );
        drop(stream);
        session.drain().unwrap();
        assert_eq!(
            session.stats().in_flight_read_windows,
            0,
            "no read-budget slot may leak across a device fault (short={short})"
        );
    }
}

/// Tentpole acceptance (ISSUE 6): a seeded fault-injected remote
/// object store — heavy-tailed first-byte latency, every 6th request
/// faulting (a ~16% fault rate, well above the required 2%) — behind
/// retry + hedged reads must decode byte-identical to a fault-free
/// serial read, while the stream holds at least 8 read-ahead windows
/// in flight from an 8-thread pool.
#[test]
fn remote_faults_recover_byte_identical_under_deep_read_ahead() {
    // Stage the file on a clean backend and capture the ground truth.
    let clean: BackendRef = Arc::new(MemBackend::new());
    build_stream_file(&clean, 2048); // 32 clusters
    let expect = {
        let r = TreeReader::open_first(Arc::new(FileReader::open(clean.clone()).unwrap()))
            .unwrap();
        r.read_all().unwrap()
    };
    let len = clean.len().unwrap() as usize;
    let mut bytes = vec![0u8; len];
    clean.read_at(0, &mut bytes).unwrap();

    // Every 6th request stalls far past the deadline (timeout flavour):
    // the fault *count* is deterministic, and consecutive request
    // indices can never both fault, so a retry or hedge always lands
    // on a healthy draw.
    let remote = Arc::new(RemoteDevice::new(
        RemoteConfig {
            first_byte_p50: Duration::from_millis(1),
            first_byte_p99: Duration::from_millis(3),
            request_slots: 16,
            seed: 21,
            fault_every_nth: 6,
            timeout_weight: 1.0,
            short_read_weight: 0.0,
            stuck_weight: 0.0,
            ..RemoteConfig::default()
        },
        1.0,
    ));
    remote.preload(0, &bytes).unwrap();

    let pool = Arc::new(Pool::new(8));
    let session = Session::with_pool(
        pool,
        SessionConfig { max_inflight_read_windows: 16, ..Default::default() },
    );
    let res = Arc::new(ResilientBackend::in_session(
        remote.clone() as BackendRef,
        ResilientConfig {
            retry: RetryPolicy {
                max_attempts: 6,
                base_backoff: Duration::from_micros(100),
                max_backoff: Duration::from_millis(2),
                ..RetryPolicy::default()
            },
            hedge: Some(HedgePolicy::at_p99(Duration::from_millis(5))),
            deadline: Some(Duration::from_millis(25)),
            ..Default::default()
        },
        &session,
    ));
    let reader = TreeReader::open_first(Arc::new(
        FileReader::open(res.clone() as BackendRef).unwrap(),
    ))
    .unwrap();
    let mut stream =
        reader.stream_in_session(&PrefetchOptions::fixed(16), &session).unwrap();
    let cols = stream.read_all_columns().unwrap();
    assert_eq!(cols, expect, "decode through remote faults must be byte-identical");
    let st = stream.stats();
    assert_eq!(st.clusters, 32);
    assert!(
        stream.admission_high_water() >= 8,
        "deep read-ahead must hold >= 8 windows in flight, got {}",
        stream.admission_high_water()
    );
    assert!(remote.device_stats().faults >= 1, "the device must actually fault");
    let rs = res.stats();
    assert!(
        rs.retries + rs.hedges >= 1,
        "stalled requests must exercise the resilience layer: {rs:?}"
    );
    assert_eq!(rs.exhausted, 0, "no request may exhaust its retry budget: {rs:?}");
    drop(stream);
    session.drain().unwrap();
    assert_eq!(session.stats().in_flight_read_windows, 0, "no leaked read-budget slot");
    // Hedge losers finish detached; their slots must drain back.
    for _ in 0..2000 {
        if session.stats().in_flight_hedges == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(session.stats().in_flight_hedges, 0, "no leaked hedge slot");
}

/// Tentpole acceptance (ISSUE 6): with the circuit breaker forced
/// open the stream must not fail — it degrades to head-only fetching
/// (no speculative read-ahead past the consumer) and still decodes
/// every cluster byte-identically.
#[test]
fn forced_open_breaker_completes_head_only() {
    let clean: BackendRef = Arc::new(MemBackend::new());
    build_stream_file(&clean, 1024); // 16 clusters
    let expect = {
        let r = TreeReader::open_first(Arc::new(FileReader::open(clean.clone()).unwrap()))
            .unwrap();
        r.read_all().unwrap()
    };
    let len = clean.len().unwrap() as usize;
    let mut bytes = vec![0u8; len];
    clean.read_at(0, &mut bytes).unwrap();

    // Fault-free remote in accounting-only mode (time_scale 0): the
    // degradation under test comes from the breaker, not the device.
    let remote = Arc::new(RemoteDevice::new(RemoteConfig::default(), 0.0));
    remote.preload(0, &bytes).unwrap();

    let pool = Arc::new(Pool::new(4));
    let session = Session::with_pool(pool, SessionConfig::default());
    let res = Arc::new(ResilientBackend::in_session(
        remote as BackendRef,
        ResilientConfig::default(),
        &session,
    ));
    res.force_breaker(true);
    let reader = TreeReader::open_first(Arc::new(
        FileReader::open(res.clone() as BackendRef).unwrap(),
    ))
    .unwrap();
    let mut stream =
        reader.stream_in_session(&PrefetchOptions::fixed(8), &session).unwrap();
    let cols = stream.read_all_columns().unwrap();
    assert_eq!(cols, expect, "a degraded stream must still decode correctly");
    let st = stream.stats();
    assert_eq!(st.clusters, 16);
    assert_eq!(
        st.degraded_windows, 16,
        "every window must have been fetched head-only: {st:?}"
    );
    drop(stream);
    session.drain().unwrap();
    assert_eq!(session.stats().in_flight_read_windows, 0);
}

/// Satellite (ISSUE 6): two writers on one file under a shared
/// session, with a seeded fraction of `write_at` ranges blipping on
/// first attempt — the resilient layer retries at the already-reserved
/// offset, so the file reads back exactly as if the device had been
/// healthy, and no cluster budget slot leaks.
#[test]
fn multi_writer_recovers_transient_write_faults() {
    let flaky = Arc::new(FaultyBackend::new(
        Arc::new(MemBackend::new()),
        FaultKind::Transient,
        FaultDirection::Writes,
        // First attempt on ~30% of ranges faults, retries always pass:
        // deterministic recovery regardless of thread interleaving.
        FaultPlan::SeededRate { seed: 9, rate: 0.3 },
    ));
    let res = Arc::new(ResilientBackend::new(
        flaky.clone() as BackendRef,
        ResilientConfig {
            retry: RetryPolicy {
                base_backoff: Duration::from_micros(50),
                max_backoff: Duration::from_micros(400),
                ..RetryPolicy::default()
            },
            ..Default::default()
        },
    ));
    let be: BackendRef = res.clone();
    let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
    let pool = Arc::new(Pool::new(3));
    let session = Session::with_pool(pool, SessionConfig::for_writers(2, 2));
    let schema = Schema::flat_f32("c", 2);
    let cfg = WriterConfig {
        basket_entries: 32,
        compression: Settings::new(Codec::Lz4r, 2),
        flush: FlushMode::Pipelined,
        ..Default::default()
    };
    std::thread::scope(|s| {
        for (name, base) in [("alpha", 0.0f32), ("beta", 1000.0f32)] {
            let sink = FileSink::new(fw.clone(), 2);
            let mut w = TreeWriter::attached(schema.clone(), sink, cfg.clone(), &session);
            let schema = schema.clone();
            s.spawn(move || {
                for i in 0..200 {
                    w.fill(vec![Value::F32(base + i as f32), Value::F32(i as f32 * 0.5)])
                        .unwrap();
                }
                let (sink, entries, _) = w.close().unwrap();
                sink.finish_tree(name.into(), schema, entries).unwrap();
            });
        }
    });
    fw.finish_registered().unwrap();
    session.drain().unwrap();
    assert_eq!(session.stats().in_flight_clusters, 0, "no leaked cluster slot");
    assert!(flaky.injected() >= 1, "the device must actually fault");
    assert!(
        res.stats().write_retries >= 1,
        "faulted appends must be retried: {:?}",
        res.stats()
    );

    // Reads are unaffected by the write-direction plan: the recovered
    // file must be complete and value-identical to what was filled.
    let file = Arc::new(FileReader::open(be).unwrap());
    for (name, base) in [("alpha", 0.0f32), ("beta", 1000.0f32)] {
        let r = TreeReader::open(file.clone(), name).unwrap();
        assert_eq!(r.entries(), 200);
        let cols = r.read_all().unwrap();
        for i in 0..200usize {
            assert_eq!(cols[0].get(i), Some(Value::F32(base + i as f32)), "{name}[{i}]");
        }
    }
}

/// Satellite (ISSUE 6): a device that dies for good mid-write must
/// surface as clean errors from the writers — no panic, no hang, no
/// retry of a permanent fault, no leaked cluster slot.
#[test]
fn multi_writer_hard_fault_surfaces_cleanly_without_leaks() {
    use std::sync::atomic::{AtomicUsize, Ordering};

    let flaky = Arc::new(FaultyBackend::new(
        Arc::new(MemBackend::new()),
        FaultKind::Hard,
        FaultDirection::Writes,
        // Header + a few appends land, then the device is gone.
        FaultPlan::AfterN(6),
    ));
    let res = Arc::new(ResilientBackend::new(
        flaky.clone() as BackendRef,
        ResilientConfig::default(),
    ));
    let be: BackendRef = res.clone();
    let fw = Arc::new(FileWriter::create(be).unwrap());
    let pool = Arc::new(Pool::new(3));
    let session = Session::with_pool(pool, SessionConfig::for_writers(2, 2));
    let schema = Schema::flat_f32("c", 2);
    let cfg = WriterConfig {
        basket_entries: 16,
        compression: Settings::new(Codec::Lz4r, 2),
        flush: FlushMode::Pipelined,
        ..Default::default()
    };
    let failures = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for name in ["alpha", "beta"] {
            let sink = FileSink::new(fw.clone(), 2);
            let mut w = TreeWriter::attached(schema.clone(), sink, cfg.clone(), &session);
            let schema = schema.clone();
            let failures = &failures;
            s.spawn(move || {
                let mut failed = false;
                for i in 0..400 {
                    if w.fill(vec![Value::F32(i as f32), Value::F32(i as f32)]).is_err() {
                        failed = true;
                        break;
                    }
                }
                // close() always drains its task group, error or not.
                match w.close() {
                    Ok((sink, entries, _)) => {
                        if sink.finish_tree(name.into(), schema, entries).is_err() {
                            failed = true;
                        }
                    }
                    Err(_) => failed = true,
                }
                if failed {
                    failures.fetch_add(1, Ordering::SeqCst);
                }
            });
        }
    });
    assert!(
        failures.load(Ordering::SeqCst) >= 1,
        "a dead device must fail at least one writer"
    );
    // Must return (success or error), never hang.
    let _ = fw.finish_registered();
    session.drain().unwrap();
    assert_eq!(session.stats().in_flight_clusters, 0, "no leaked cluster slot");
    assert_eq!(
        res.stats().write_retries,
        0,
        "permanent faults must not be retried: {:?}",
        res.stats()
    );
    assert!(flaky.injected() >= 1);
}

/// Satellite (ISSUE 6): `hadd` merging through a blippy output device
/// retries to a byte-identical merged file. Serial merge + every-3rd
/// write faulting makes both the fault count and the recovery fully
/// deterministic (the retry is never the 3rd-next call).
#[test]
fn hadd_through_transient_output_faults_is_byte_identical() {
    use rootio_par::hadd::{hadd, HaddOptions};

    let mk_input = |base: f32| -> BackendRef {
        let be: BackendRef = Arc::new(MemBackend::new());
        let schema = Schema::flat_f32("c", 2);
        let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
        let sink = FileSink::new(fw.clone(), 2);
        let cfg = WriterConfig {
            basket_entries: 32,
            compression: Settings::new(Codec::Lz4r, 2),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        for i in 0..100 {
            w.fill(vec![Value::F32(base + i as f32), Value::F32(i as f32)]).unwrap();
        }
        let (sink, n, _) = w.close().unwrap();
        let meta = sink.into_meta("t".into(), schema, n).unwrap();
        fw.finish(&Directory { trees: vec![meta] }).unwrap();
        be
    };
    let inputs = [mk_input(0.0), mk_input(500.0)];
    let opts = HaddOptions { parallel: false, ..Default::default() };

    let clean_out: BackendRef = Arc::new(MemBackend::new());
    hadd(clean_out.clone(), &inputs, &opts).unwrap();

    let flaky = Arc::new(FaultyBackend::new(
        Arc::new(MemBackend::new()),
        FaultKind::Transient,
        FaultDirection::Writes,
        FaultPlan::EveryNth(3),
    ));
    let res = Arc::new(ResilientBackend::new(
        flaky.clone() as BackendRef,
        ResilientConfig {
            retry: RetryPolicy {
                base_backoff: Duration::from_micros(50),
                max_backoff: Duration::from_micros(400),
                ..RetryPolicy::default()
            },
            ..Default::default()
        },
    ));
    let faulty_out: BackendRef = res.clone();
    hadd(faulty_out.clone(), &inputs, &opts).unwrap();

    let len = clean_out.len().unwrap();
    assert_eq!(len, faulty_out.len().unwrap(), "merged files must be the same size");
    let mut a = vec![0u8; len as usize];
    let mut b = vec![0u8; len as usize];
    clean_out.read_at(0, &mut a).unwrap();
    faulty_out.read_at(0, &mut b).unwrap();
    assert_eq!(a, b, "retried writes must land byte-identical");
    assert!(
        res.stats().write_retries >= 1,
        "every 3rd output write faults: {:?}",
        res.stats()
    );
    assert!(flaky.injected() >= 1);
}
