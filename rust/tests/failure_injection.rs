//! Failure injection: random corruption of stored files must surface
//! as errors (checksum/format/codec), never panics or silent bad data.

mod common;

use std::sync::Arc;

use common::{property, Gen};
use rootio_par::compress::{Codec, Settings};
use rootio_par::format::reader::FileReader;
use rootio_par::format::writer::FileWriter;
use rootio_par::format::Directory;
use rootio_par::serial::value::Value;
use rootio_par::storage::mem::MemBackend;
use rootio_par::storage::{Backend, BackendRef};
use rootio_par::tree::reader::TreeReader;
use rootio_par::tree::sink::FileSink;
use rootio_par::tree::writer::{FlushMode, TreeWriter, WriterConfig};

fn build_file(g: &mut Gen) -> BackendRef {
    let schema = g.schema(4);
    let be: BackendRef = Arc::new(MemBackend::new());
    let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
    let sink = FileSink::new(fw.clone(), schema.len());
    let cfg = WriterConfig {
        basket_entries: g.range(4, 40),
        compression: if g.bool() {
            Settings::new(Codec::Rzip, 3)
        } else {
            Settings::new(Codec::Lz4r, 3)
        },
        flush: FlushMode::Serial,
        ..Default::default()
    };
    let mut w = TreeWriter::new(schema.clone(), sink, cfg);
    for _ in 0..g.range(10, 200) {
        let row = g.row(&schema);
        w.fill(row).unwrap();
    }
    let (sink, entries, _) = w.close().unwrap();
    let meta = sink.into_meta("t".into(), schema, entries).unwrap();
    fw.finish(&Directory { trees: vec![meta] }).unwrap();
    be
}

/// Read everything; any Err is acceptable, panics are not. Returns
/// whether every stage succeeded (i.e. corruption went undetected).
fn try_full_read(be: BackendRef) -> bool {
    let Ok(file) = FileReader::open(be) else { return false };
    let Ok(reader) = TreeReader::open_first(Arc::new(file)) else { return false };
    match reader.read_all() {
        Ok(cols) => reader.rows(&cols).is_ok(),
        Err(_) => false,
    }
}

#[test]
fn random_byte_corruption_never_panics() {
    property(60, |g| {
        let be = build_file(g);
        let len = be.len().unwrap() as usize;
        // corrupt 1..4 random bytes
        for _ in 0..g.range(1, 5) {
            let off = g.range(0, len);
            let b = g.u32() as u8;
            be.write_at(off as u64, &[b]).unwrap();
        }
        // must not panic; detection is expected but single-byte writes
        // can hit slack space (e.g. rewrite the same value)
        let _ = try_full_read(be);
    });
}

#[test]
fn payload_corruption_is_detected() {
    property(40, |g| {
        let be = build_file(g);
        let len = be.len().unwrap() as usize;
        // Flip a bit strictly inside the basket payload region
        // (after the 24-byte header, before the footer) — guaranteed
        // to be covered by a basket CRC.
        let file = FileReader::open(be.clone()).unwrap();
        let tree = &file.directory().trees[0];
        let br = &tree.branches[g.range(0, tree.branches.len())];
        let k = &br.baskets[g.range(0, br.baskets.len())];
        let off = k.offset + g.range(0, k.comp_len as usize) as u64;
        drop(file);
        let mut cur = [0u8; 1];
        be.read_at(off, &mut cur).unwrap();
        be.write_at(off, &[cur[0] ^ 0x40]).unwrap();
        let _ = len;
        assert!(
            !try_full_read(be),
            "bit flip inside a basket payload must be detected by CRC"
        );
    });
}

#[test]
fn truncated_files_are_rejected() {
    property(25, |g| {
        let be = build_file(g);
        let len = be.len().unwrap() as usize;
        let keep = g.range(0, len);
        let mut data = vec![0u8; len];
        be.read_at(0, &mut data).unwrap();
        let truncated: BackendRef = Arc::new(MemBackend::from_vec(data[..keep].to_vec()));
        assert!(
            !try_full_read(truncated),
            "truncation to {keep}/{len} bytes must not read back cleanly"
        );
    });
}

#[test]
fn header_corruption_is_rejected() {
    let mut g = Gen::new(7);
    let be = build_file(&mut g);
    for off in [0u64, 1, 4, 8, 16] {
        let mut cur = [0u8; 1];
        be.read_at(off, &mut cur).unwrap();
        be.write_at(off, &[cur[0] ^ 0xFF]).unwrap();
        assert!(!try_full_read(be.clone()), "header byte {off} corruption");
        be.write_at(off, &cur).unwrap(); // restore
        assert!(try_full_read(be.clone()), "restore at byte {off}");
    }
}

/// Backend wrapper that fails — or short-reads — `read_at` once its
/// healthy-call budget runs out: the mid-window device fault the
/// prefetcher must surface cleanly (ISSUE 5).
struct FlakyBackend {
    inner: BackendRef,
    remaining: std::sync::atomic::AtomicI64,
    /// `true`: deliver only half the requested range (the rest stays
    /// zeroed) so CRC verification has to catch it; `false`: a hard
    /// `Err` from the device.
    short: bool,
}

impl Backend for FlakyBackend {
    fn read_at(&self, off: u64, buf: &mut [u8]) -> rootio_par::error::Result<()> {
        use std::sync::atomic::Ordering;
        if self.remaining.fetch_sub(1, Ordering::SeqCst) <= 0 {
            if self.short {
                let half = buf.len() / 2;
                return self.inner.read_at(off, &mut buf[..half]);
            }
            return Err(rootio_par::error::Error::Io(std::io::Error::other(
                "injected device failure",
            )));
        }
        self.inner.read_at(off, buf)
    }

    fn write_at(&self, off: u64, data: &[u8]) -> rootio_par::error::Result<()> {
        self.inner.write_at(off, data)
    }

    fn len(&self) -> rootio_par::error::Result<u64> {
        self.inner.len()
    }

    fn describe(&self) -> String {
        format!("flaky({})", self.inner.describe())
    }
}

/// Satellite (ISSUE 5): a failing or short `read_at` mid-window must
/// propagate as an error through the prefetcher — no hang, no leaked
/// read-budget slot, the session still drains cleanly.
#[test]
fn prefetcher_surfaces_device_faults_without_hang_or_leaked_slots() {
    use rootio_par::cache::PrefetchOptions;
    use rootio_par::imt::Pool;
    use rootio_par::serial::schema::Schema;
    use rootio_par::session::{Session, SessionConfig};

    // Healthy 8-cluster file: 2 branches × 512 rows at 64 per basket.
    let schema = Schema::flat_f32("c", 2);
    let inner: BackendRef = Arc::new(MemBackend::new());
    let fw = Arc::new(FileWriter::create(inner.clone()).unwrap());
    let sink = FileSink::new(fw.clone(), 2);
    let cfg = WriterConfig {
        basket_entries: 64,
        compression: Settings::new(Codec::Lz4r, 2),
        flush: FlushMode::Serial,
        ..Default::default()
    };
    let mut w = TreeWriter::new(schema.clone(), sink, cfg);
    for i in 0..512 {
        w.fill(vec![Value::F32(i as f32), Value::F32(i as f32 * 0.5)]).unwrap();
    }
    let (sink, entries, _) = w.close().unwrap();
    let meta = sink.into_meta("t".into(), schema, entries).unwrap();
    fw.finish(&Directory { trees: vec![meta] }).unwrap();

    let pool = Arc::new(Pool::new(3));
    for short in [false, true] {
        // Open with an unlimited budget (however many reads the open
        // path needs), then arm the fault: 3 healthy window fetches,
        // a later window's fetch fails mid-stream while earlier
        // clusters are being consumed.
        let flaky = Arc::new(FlakyBackend {
            inner: inner.clone(),
            remaining: std::sync::atomic::AtomicI64::new(i64::MAX),
            short,
        });
        let be: BackendRef = flaky.clone();
        let reader =
            TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        flaky.remaining.store(3, std::sync::atomic::Ordering::SeqCst);
        let session = Session::with_pool(pool.clone(), SessionConfig::default());
        let mut stream = reader
            .stream_in_session(&PrefetchOptions::fixed(2), &session)
            .unwrap();
        let mut consumed = 0usize;
        loop {
            match stream.next() {
                Ok(Some(_)) => consumed += 1,
                Ok(None) => panic!("stream must fail before the end (short={short})"),
                Err(_) => break, // Io or checksum Format — both are clean surfaces
            }
        }
        assert!(
            consumed < 8,
            "the fault must land mid-stream, yet {consumed}/8 clusters decoded"
        );
        assert!(
            stream.next().is_err(),
            "a failed stream must stay failed (short={short})"
        );
        drop(stream);
        session.drain().unwrap();
        assert_eq!(
            session.stats().in_flight_read_windows,
            0,
            "no read-budget slot may leak across a device fault (short={short})"
        );
    }
}
