//! End-to-end integration: all three layers composed — PJRT event
//! generation (L1/L2 artifacts) → columnar write with parallel branch
//! compression → file → parallel read / basket pipeline → PJRT
//! analysis. Tests are skipped (with a note) when artifacts are not
//! built; `make test` always builds them first.

mod common;

use std::sync::Arc;

use rootio_par::compress::{Codec, Settings};
use rootio_par::coordinator::baskets::{self, PipelineOptions};
use rootio_par::coordinator::read::{read_columns, ReadOptions};
use rootio_par::experiments::util::{synthesize_dataset, synthesize_physics_file};
use rootio_par::format::reader::FileReader;
use rootio_par::framework::dataset::DatasetKind;
use rootio_par::framework::{self, FrameworkConfig, OutputMode};
use rootio_par::runtime::Engine;
use rootio_par::storage::mem::MemBackend;
use rootio_par::storage::BackendRef;
use rootio_par::tree::reader::TreeReader;

fn engine() -> Option<Engine> {
    match Engine::load_default() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping end-to-end test (artifacts not built): {e}");
            None
        }
    }
}

#[test]
fn generate_write_read_analyze_full_stack() {
    let Some(engine) = engine() else { return };
    let entries = 4096 * 4;
    let (be, wrep) =
        synthesize_physics_file(entries, Settings::new(Codec::Rzip, 3), Some(&engine)).unwrap();
    assert_eq!(wrep.entries, entries as u64);
    assert!(wrep.compression_ratio() > 1.0, "physics columns must compress");

    let reader = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();

    // Parallel column read reproduces the bytes PJRT generated.
    rootio_par::imt::enable(4);
    let rep = read_columns(&reader, &ReadOptions::default()).unwrap();
    let ev0 = engine.generate(1, 0, 4096).unwrap();
    let col0 = rep.columns[0].as_f32().unwrap();
    assert_eq!(&col0[..4096], &ev0.column(0)[..], "column 0 of block 0 matches the generator");

    // The basket pipeline analyzes every event, and the histogram the
    // Pallas kernel computes matches a direct analysis of the blocks.
    let pipe = baskets::run(&reader, Some(&engine), &PipelineOptions::default()).unwrap();
    rootio_par::imt::disable();
    assert_eq!(pipe.analyzed, entries as u64);
    let hist = pipe.hist.unwrap();
    assert_eq!(hist.iter().sum::<f32>() as usize, entries);

    let mut want = vec![0f32; engine.meta().nbins];
    for blk in 0..4 {
        let ev = engine.generate(blk as u32 + 1, 0, 4096).unwrap();
        let res = engine.analyze_block(&ev).unwrap();
        for (w, v) in want.iter_mut().zip(&res.hist) {
            *w += v;
        }
    }
    assert_eq!(hist, want, "pipeline histogram == direct per-block analysis");
}

#[test]
fn framework_with_engine_writes_readable_reco() {
    let Some(engine) = engine() else { return };
    let block = engine.meta().blocks[0];
    let cfg = FrameworkConfig {
        streams: 3,
        blocks_per_stream: 2,
        block,
        dataset: DatasetKind::Reco,
        output: OutputMode::ImtMerger,
        compression: Settings::new(Codec::Lz4r, 4),
        queue_depth: 4,
    };
    rootio_par::imt::enable(2);
    let be: BackendRef = Arc::new(MemBackend::new());
    let rep = framework::run(&cfg, be.clone(), Some(&engine), None).unwrap();
    rootio_par::imt::disable();
    assert_eq!(rep.events, (3 * 2 * block) as u64);
    let reader = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
    assert_eq!(reader.entries(), rep.events);
    assert_eq!(reader.n_branches(), 48);
    // every branch fully decodes
    let cols = reader.read_all().unwrap();
    assert!(cols.iter().all(|c| c.len() == rep.events as usize));
}

#[test]
fn dataset_files_are_deterministic_given_engine() {
    let Some(engine) = engine() else { return };
    let mk = || {
        let (be, _) = synthesize_dataset(
            DatasetKind::Aod,
            8192,
            2048,
            Settings::new(Codec::Rzip, 4),
            Some(&engine),
        )
        .unwrap();
        use rootio_par::storage::Backend;
        let mut buf = vec![0u8; be.len().unwrap() as usize];
        be.read_at(0, &mut buf).unwrap();
        buf
    };
    assert_eq!(mk(), mk(), "same seed schedule -> byte-identical files");
}

#[test]
fn imt_on_off_produce_identical_files_from_engine_blocks() {
    let Some(engine) = engine() else { return };
    let run = |threads: usize| {
        if threads > 0 {
            rootio_par::imt::enable(threads);
        } else {
            rootio_par::imt::disable();
        }
        let (be, _) = synthesize_physics_file(8192, Settings::new(Codec::Rzip, 4), Some(&engine))
            .unwrap();
        rootio_par::imt::disable();
        let reader = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        reader.read_all().unwrap()
    };
    assert_eq!(run(0), run(4), "IMT must not change stored content");
}
