//! Observability acceptance: the traced pipeline covers every layer,
//! the useful-work fraction of a real 8-worker read clears its pinned
//! floor, the metrics registry reconciles exactly with the stats
//! structs it folds in, and the exporters survive degenerate spans.

use std::sync::Arc;
use std::time::Duration;

use rootio_par::cache::{Predicate, PrefetchOptions};
use rootio_par::compress::{Codec, Settings};
use rootio_par::coordinator::write::write_blocks_in_session;
use rootio_par::experiments::util::synthesize_flat_f32;
use rootio_par::format::reader::FileReader;
use rootio_par::framework::chain::Chain;
use rootio_par::imt::Pool;
use rootio_par::metrics::{json, Recorder, SpanKind};
use rootio_par::serial::column::ColumnData;
use rootio_par::serial::schema::Schema;
use rootio_par::session::{Session, SessionConfig};
use rootio_par::storage::mem::MemBackend;
use rootio_par::storage::BackendRef;
use rootio_par::tree::reader::TreeReader;
use rootio_par::tree::writer::{FlushMode, Layout, WriterConfig};

/// Write `files` paged files through `session` with a chain-monotone
/// branch 0 (so a later predicate scan can zone-prune) — the same
/// pipeline `rootio trace bench` runs.
fn write_chain_files(session: &Session, files: usize, entries: usize) -> Vec<BackendRef> {
    let n_branches = 8usize;
    let schema = Schema::flat_f32("b", n_branches);
    let cfg = WriterConfig {
        basket_entries: 512,
        compression: Settings::new(Codec::Lz4r, 3),
        flush: FlushMode::Pipelined,
        max_inflight_clusters: 2,
        layout: Layout::Paged { page_entries: 128 },
        ..Default::default()
    };
    (0..files)
        .map(|f| {
            let be: BackendRef = Arc::new(MemBackend::new());
            let block: Vec<ColumnData> = (0..n_branches)
                .map(|b| {
                    ColumnData::F32(
                        (0..entries)
                            .map(|i| {
                                if b == 0 {
                                    (f * entries + i) as f32
                                } else {
                                    ((i * 31 + b * 7 + f) % 499) as f32
                                }
                            })
                            .collect(),
                    )
                })
                .collect();
            write_blocks_in_session(
                session,
                be.clone(),
                schema.clone(),
                "events",
                cfg.clone(),
                vec![block],
            )
            .unwrap();
            be
        })
        .collect()
}

/// The `rootio trace bench` pipeline end to end: a tight-budget
/// pipelined write of a small chain, then a predicate scan of it, all
/// into one recorder — spans from at least five distinct subsystems
/// must land, the Chrome export must parse, and the pruned scan must
/// really prune.
#[test]
fn traced_chain_scan_covers_five_subsystems() {
    rootio_par::imt::enable(8);
    let entries = 4_096usize;
    let files = 3usize;
    let rec = Recorder::new();
    let session = Session::new(SessionConfig {
        max_inflight_clusters: 2,
        recorder: rec.clone(),
        ..Default::default()
    });
    let backends = write_chain_files(&session, files, entries);
    session.drain().unwrap();

    let cutoff = (files * entries) as f64 * 0.9;
    let chain = Chain::new(backends).with_recorder(rec.clone());
    let mut rows = 0u64;
    let rep = chain
        .scan_where(Predicate::ge(0, cutoff), &PrefetchOptions::fixed(4), |b| {
            rows += b.rows() as u64
        })
        .unwrap();
    assert_eq!(rep.files, files as u64);
    assert_eq!(rows, rep.rows);
    assert!(rep.prefetch.pages_pruned > 0, "zone maps must prune the bottom 90%");
    rec.check().unwrap();

    // Spans from >= 5 distinct subsystems, and specifically the layers
    // the acceptance criteria name.
    let spans = rec.snapshot();
    assert!(!spans.is_empty());
    let mut subs: Vec<&str> = spans.iter().map(|s| s.kind.subsystem()).collect();
    subs.sort_unstable();
    subs.dedup();
    assert!(subs.len() >= 5, "only {} subsystems traced: {subs:?}", subs.len());
    for want in ["pool", "writer", "prefetch", "storage", "chain"] {
        assert!(subs.contains(&want), "missing '{want}' spans: {subs:?}");
    }

    // The Chrome export is valid JSON with the same subsystem spread.
    let doc = json::parse(&rec.to_chrome_json()).unwrap();
    let events = doc.get("traceEvents").and_then(json::Json::as_arr).unwrap();
    assert_eq!(events.len(), spans.len());
    let mut cats: Vec<&str> =
        events.iter().filter_map(|e| e.get("cat").and_then(json::Json::as_str)).collect();
    cats.sort_unstable();
    cats.dedup();
    assert!(cats.len() >= 5, "chrome export lost categories: {cats:?}");
    for e in events {
        assert_eq!(e.get("ph").and_then(json::Json::as_str), Some("X"));
        assert!(e.get("dur").and_then(json::Json::as_f64).unwrap() > 0.0);
    }
}

/// Fig2-shaped acceptance: a real parallel read on an 8-worker pool
/// must clear a pinned useful-work floor. The floor is deliberately
/// loose (CI machines vary wildly); the regression it guards against
/// is tracing going blind (no useful spans at all) or the accounting
/// double-counting itself above 1.0.
#[test]
fn eight_worker_read_useful_fraction_floor() {
    let be = synthesize_flat_f32(16, 32_768, 1_024, Settings::new(Codec::Rzip, 4)).unwrap();
    let pool = Arc::new(Pool::new(8));
    let rec = Recorder::new();
    let session = Session::with_pool(
        pool,
        SessionConfig { recorder: rec.clone(), ..Default::default() },
    );
    let reader = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
    let mut stream =
        reader.stream_in_session(&PrefetchOptions::fixed(4), &session).unwrap();
    let cols = stream.read_all_columns().unwrap();
    assert_eq!(cols.len(), 16);
    rec.check().unwrap();

    let (useful, wall) = rec.useful_per_thread();
    assert!(!useful.is_empty());
    assert!(!wall.is_zero());
    let frac = rec.useful_fraction();
    assert!(frac >= 0.02, "useful fraction {frac:.4} under the 0.02 floor");
    assert!(frac <= 1.0, "useful fraction {frac:.4} over 1.0 — double-counting");
    // Decode work must actually be on the pool, not just the consumer.
    assert!(
        rec.snapshot().iter().any(|s| s.kind == SpanKind::Decompress),
        "no decompress spans recorded"
    );
}

/// The registry snapshot must reconcile *exactly* with the stats
/// structs it folds in: the selected/pruned/skipped byte partition
/// sums to the tree's stored bytes, every mirrored counter matches,
/// and the session's in-flight gauges never exceed their limits.
#[test]
fn registry_reconciles_bytes_and_budgets() {
    let be = synthesize_flat_f32(8, 16_384, 1_024, Settings::new(Codec::Lz4r, 3)).unwrap();
    let file = Arc::new(FileReader::open(be).unwrap());
    let tree_bytes: u64 = file.directory().trees[0]
        .branches
        .iter()
        .map(|br| br.stored_bytes())
        .sum();

    let pool = Arc::new(Pool::new(4));
    let session = Session::with_pool(pool, SessionConfig::default());
    let reader = TreeReader::open_first(file).unwrap();
    let mut stream =
        reader.stream_in_session(&PrefetchOptions::fixed(4), &session).unwrap();
    stream.read_all_columns().unwrap();
    let st = stream.stats();

    let mut snap = session.metrics().snapshot();
    snap.put_prefetch("prefetch", &st);
    snap.put_session(&session.stats());

    // Byte partition: selected + pruned + skipped == the tree's stored
    // bytes, and a full unfiltered read consumed all of the selection.
    let selected = snap.counter("prefetch.bytes_selected").unwrap();
    let pruned = snap.counter("prefetch.bytes_pruned").unwrap();
    let skipped = snap.counter("prefetch.bytes_skipped").unwrap();
    assert_eq!(selected + pruned + skipped, tree_bytes);
    assert_eq!(snap.counter("prefetch.stored_bytes"), Some(selected));

    // Every mirrored counter is the stats struct's value, exactly.
    assert_eq!(snap.counter("prefetch.clusters"), Some(st.clusters));
    assert_eq!(snap.counter("prefetch.baskets"), Some(st.baskets));
    assert_eq!(snap.counter("prefetch.device_reads"), Some(st.device_reads));
    assert_eq!(snap.counter("prefetch.retries"), Some(st.retries));

    // Live histograms: one window-latency sample per consumed window,
    // device reads timed for every scatter fetch.
    let wl = snap.hist("window_latency").unwrap();
    assert_eq!(wl.count(), stream.window_latency().count());
    assert!(wl.count() > 0);
    assert!(snap.hist("device_read").unwrap().count() > 0);

    // Budget gauges: in-flight high-waters can never exceed limits.
    let ss = session.stats();
    assert!(ss.in_flight_read_windows <= ss.read_budget_limit);
    assert!(ss.in_flight_clusters <= ss.budget_limit);
    assert!(ss.in_flight_hedges <= ss.hedge_limit);
    let g = |n: &str| snap.gauge(n).unwrap();
    assert!(g("session.in_flight_read_windows") <= g("session.read_budget_limit"));
    assert!(g("session.in_flight_clusters") <= g("session.budget_limit"));
    assert!(g("session.in_flight_hedges") <= g("session.hedge_limit"));

    // The JSON dump parses back with the same numbers.
    let doc = json::parse(&snap.to_json()).unwrap();
    assert_eq!(
        doc.get("counters")
            .and_then(|c| c.get("prefetch.stored_bytes"))
            .and_then(json::Json::as_f64),
        Some(selected as f64)
    );
}

/// Zero-duration marks, end-before-start and out-of-order spans must
/// render, export and account without panicking — a poisoned or racy
/// producer can hand the exporters anything.
#[test]
fn exporters_survive_degenerate_spans() {
    let rec = Recorder::new();
    rec.mark(SpanKind::BreakerTrip); // zero-width event
    rec.mark(SpanKind::ZonePrune);
    let t = rec.elapsed();
    rec.push(SpanKind::Decompress, t, t); // zero duration
    rec.push(SpanKind::Fetch, t + Duration::from_micros(50), t); // end < start
    rec.push(
        // out of order vs the spans above
        SpanKind::Compress,
        t.saturating_sub(Duration::from_micros(10)),
        t.saturating_sub(Duration::from_micros(5)),
    );

    let (useful, wall) = rec.useful_per_thread();
    assert!(useful.iter().all(|d| *d <= wall.max(Duration::from_micros(100))));
    let f = rec.useful_fraction();
    assert!((0.0..=1.0).contains(&f), "fraction {f}");
    let ascii = rec.timeline_ascii(60);
    assert!(ascii.contains("legend") || ascii.is_empty());
    let _ = rec.to_csv();
    let doc = json::parse(&rec.to_chrome_json()).unwrap();
    for e in doc.get("traceEvents").and_then(json::Json::as_arr).unwrap() {
        assert!(e.get("dur").and_then(json::Json::as_f64).unwrap() >= 0.0);
    }
    rec.check().unwrap();
}

/// A disabled recorder records nothing, costs one branch per call and
/// still satisfies the whole exporter surface.
#[test]
fn disabled_recorder_is_inert() {
    let rec = Recorder::disabled();
    assert!(!rec.is_enabled());
    rec.mark(SpanKind::BreakerTrip);
    rec.push(SpanKind::Fetch, Duration::ZERO, Duration::from_micros(5));
    let out = rec.record(SpanKind::Compress, || 41 + 1);
    assert_eq!(out, 42);
    assert!(rec.snapshot().is_empty());
    assert_eq!(rec.n_threads(), 0);
    assert_eq!(rec.useful_fraction(), 0.0);
    assert!(rec.timeline_ascii(60).is_empty());
    rec.check().unwrap();
    // Two disabled handles are "the same" (neither records); an
    // enabled handle is only the same as its own clones.
    assert!(rec.same(&Recorder::disabled()));
    let on = Recorder::new();
    assert!(on.same(&on.clone()));
    assert!(!on.same(&Recorder::new()));
    assert!(!on.same(&rec));
}
