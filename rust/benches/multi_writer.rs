//! Bench harness for the multi-writer session experiment (harness =
//! false; criterion is unavailable offline — see Cargo.toml). Pass
//! --quick for a reduced sweep. Emits BENCH_fig4.json.
fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    match rootio_par::experiments::multi_writer(quick) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("multi_writer: {e}");
            std::process::exit(1);
        }
    }
}
