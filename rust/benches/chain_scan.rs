//! Bench harness for the chained-dataset zone-map predicate-pushdown
//! experiment (harness = false; criterion is unavailable offline — see
//! Cargo.toml). Pass --quick for the reduced chain. Emits
//! BENCH_fig10.json.
fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    match rootio_par::experiments::chain_scan(quick) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("chain_scan: {e}");
            std::process::exit(1);
        }
    }
}
