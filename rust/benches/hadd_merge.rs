//! Bench harness for the §3.4 hadd experiment (harness = false).
fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    match rootio_par::experiments::hadd_bench(quick) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("hadd_merge: {e}");
            std::process::exit(1);
        }
    }
}
