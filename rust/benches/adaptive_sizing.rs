//! Bench harness for the adaptive cluster sizing experiment (harness =
//! false; criterion is unavailable offline — see Cargo.toml). Pass
//! --quick for a reduced sweep. Emits BENCH_fig5.json.
fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    match rootio_par::experiments::adaptive_sizing(quick) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("adaptive_sizing: {e}");
            std::process::exit(1);
        }
    }
}
