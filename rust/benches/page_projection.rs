//! Bench harness for the paged-layout projection-pushdown experiment
//! (harness = false; criterion is unavailable offline — see
//! Cargo.toml). Pass --quick for the reduced dataset. Emits
//! BENCH_fig9.json.
fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    match rootio_par::experiments::page_projection(quick) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("page_projection: {e}");
            std::process::exit(1);
        }
    }
}
