//! Bench harness for the paper's fig3 experiment (harness = false;
//! criterion is unavailable offline — see Cargo.toml). Pass --quick
//! for a reduced sweep.
fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    match rootio_par::experiments::fig3(quick) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("fig3_parallel_write: {e}");
            std::process::exit(1);
        }
    }
}
