//! Bench harness for the fault-tolerant remote storage experiment
//! (harness = false; criterion is unavailable offline — see
//! Cargo.toml). Pass --quick for a reduced fault-rate sweep. Emits
//! BENCH_fig7.json.
fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    match rootio_par::experiments::remote_reads(quick) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("remote_reads: {e}");
            std::process::exit(1);
        }
    }
}
