//! Overhead guard for the observability layer (harness = false;
//! criterion is unavailable offline — see Cargo.toml).
//!
//! Runs the same 8-worker prefetch read three ways — plain (the
//! product default, whose session carries a disabled recorder), with
//! an explicitly disabled recorder, and fully traced — and asserts the
//! cost envelope the tracing design promises: a disabled recorder is
//! within 1% of the untraced wall (it is the same one-branch code
//! path), and an enabled recorder stays under 5%. Min-of-N walls so a
//! noisy scheduler tick can't fail the guard.

use std::sync::Arc;
use std::time::{Duration, Instant};

use rootio_par::cache::PrefetchOptions;
use rootio_par::compress::{Codec, Settings};
use rootio_par::experiments::util::synthesize_flat_f32;
use rootio_par::format::reader::FileReader;
use rootio_par::imt::Pool;
use rootio_par::metrics::Recorder;
use rootio_par::session::{Session, SessionConfig};
use rootio_par::tree::reader::TreeReader;

fn scan(file: &Arc<FileReader>, pool: &Arc<Pool>, recorder: Recorder) -> Duration {
    let session = Session::with_pool(
        pool.clone(),
        SessionConfig { recorder, ..Default::default() },
    );
    let reader = TreeReader::open_first(file.clone()).unwrap();
    let t0 = Instant::now();
    let mut stream =
        reader.stream_in_session(&PrefetchOptions::fixed(4), &session).unwrap();
    stream.read_all_columns().unwrap();
    t0.elapsed()
}

fn min_of(n: usize, mut f: impl FnMut() -> Duration) -> Duration {
    (0..n).map(|_| f()).min().unwrap()
}

fn pct(x: Duration, base: Duration) -> f64 {
    (x.as_secs_f64() / base.as_secs_f64().max(1e-12) - 1.0) * 100.0
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (entries, trials) = if quick { (16_384, 5) } else { (65_536, 9) };
    let be =
        synthesize_flat_f32(16, entries, 1_024, Settings::new(Codec::Rzip, 4)).unwrap();
    let file = Arc::new(FileReader::open(be).unwrap());
    let pool = Arc::new(Pool::new(8));

    // Warm the pool, the scratch buffers and the page cache.
    for _ in 0..2 {
        scan(&file, &pool, Recorder::disabled());
    }

    let untraced = min_of(trials, || scan(&file, &pool, Recorder::disabled()));
    let disabled = min_of(trials, || scan(&file, &pool, Recorder::disabled()));
    let traced = {
        let rec = Recorder::new();
        let wall = min_of(trials, || scan(&file, &pool, rec.clone()));
        let spans = rec.snapshot().len();
        println!("traced runs recorded {spans} spans");
        wall
    };

    println!(
        "untraced  {:>9.3} ms\ndisabled  {:>9.3} ms ({:+.2}%)\ntraced    {:>9.3} ms ({:+.2}%)",
        untraced.as_secs_f64() * 1e3,
        disabled.as_secs_f64() * 1e3,
        pct(disabled, untraced),
        traced.as_secs_f64() * 1e3,
        pct(traced, untraced),
    );

    // Small absolute slack so microsecond-scale walls can't trip the
    // percentage gates on timer granularity alone.
    let slack = Duration::from_micros(500);
    assert!(
        disabled <= untraced.mul_f64(1.01) + slack,
        "disabled-recorder overhead {:+.2}% exceeds the 1% envelope",
        pct(disabled, untraced)
    );
    assert!(
        traced <= untraced.mul_f64(1.05) + slack,
        "enabled-recorder overhead {:+.2}% exceeds the 5% envelope",
        pct(traced, untraced)
    );
    println!("trace overhead OK");
}
