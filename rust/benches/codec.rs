//! Codec ratio/throughput characterisation (harness = false).
fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    match rootio_par::experiments::codec_bench(quick) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("codec: {e}");
            std::process::exit(1);
        }
    }
}
