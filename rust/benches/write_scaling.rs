//! Bench harness for the write-scaling experiment (harness = false;
//! criterion is unavailable offline — see Cargo.toml). Pass --quick
//! for a reduced sweep. Emits BENCH_fig3.json.
fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    match rootio_par::experiments::write_scaling(quick) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("write_scaling: {e}");
            std::process::exit(1);
        }
    }
}
