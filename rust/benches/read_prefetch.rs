//! Bench harness for the parallel read-ahead cache experiment
//! (harness = false; criterion is unavailable offline — see
//! Cargo.toml). Pass --quick for a reduced device sweep. Emits
//! BENCH_fig6.json.
fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    match rootio_par::experiments::read_prefetch(quick) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("read_prefetch: {e}");
            std::process::exit(1);
        }
    }
}
