//! Trace a parallel scan end to end and export it for Perfetto.
//!
//! Writes a small compressed tree into an in-memory backend, reads it
//! back through a traced 4-worker session, prints the per-thread ASCII
//! timeline plus the useful-work fraction, and drops both a Chrome
//! trace-event file (`trace.json` — load it at https://ui.perfetto.dev)
//! and a metrics-registry snapshot (`stats.json`) in the working dir.
//!
//! Run with: cargo run --release --example trace_a_scan

use std::sync::Arc;

use rootio_par::cache::PrefetchOptions;
use rootio_par::compress::{Codec, Settings};
use rootio_par::error::Result;
use rootio_par::experiments::util::synthesize_flat_f32;
use rootio_par::format::reader::FileReader;
use rootio_par::imt::Pool;
use rootio_par::session::{Session, SessionConfig};
use rootio_par::tree::reader::TreeReader;

fn main() -> Result<()> {
    // A 16-branch, 32k-entry compressed file, entirely in memory.
    let backend = synthesize_flat_f32(16, 32_768, 1_024, Settings::new(Codec::Rzip, 4))?;

    // A traced session: every pool task, budget wait, device read and
    // basket decode lands in the recorder as a timestamped span.
    let pool = Arc::new(Pool::new(4));
    let session = Session::with_pool(pool, SessionConfig::default().traced());

    let reader = TreeReader::open_first(Arc::new(FileReader::open(backend)?))?;
    let mut stream = reader.stream_in_session(&PrefetchOptions::fixed(4), &session)?;
    let columns = stream.read_all_columns()?;

    let rec = session.recorder();
    rec.check()?;
    println!("{}", rec.timeline_ascii(100));
    println!(
        "read {} columns; {} spans on {} threads; useful fraction {:.3}",
        columns.len(),
        rec.snapshot().len(),
        rec.n_threads(),
        rec.useful_fraction()
    );

    // Perfetto-loadable trace + the unified metrics snapshot.
    std::fs::write("trace.json", rec.to_chrome_json())?;
    let mut snap = session.metrics().snapshot();
    snap.put_prefetch("prefetch", &stream.stats());
    snap.put_session(&session.stats());
    std::fs::write("stats.json", snap.to_json())?;
    println!("wrote trace.json and stats.json");
    Ok(())
}
